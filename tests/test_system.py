"""End-to-end behaviour tests for the K-FAC framework."""
import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.configs.base import KFACConfig, TrainConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticLMData
from repro.models.lm import LM
from repro.training.trainer import Trainer


def test_lm_train_end_to_end():
    """Reduced llama on synthetic Markov tokens: loss must drop (the data is
    predictable, so a working optimizer learns the transition fast)."""
    cfg = get_reduced_config("llama3.2-1b")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    data = SyntheticLMData(cfg.vocab_size, seq=24, global_batch=8, noise=0.05)
    kcfg = KFACConfig(lambda_init=10.0, t3=3, t1=3, t2=100)
    tr = Trainer(lm, KFAC(lm, kcfg), TrainConfig(steps=12, log_every=100),
                 None, None)
    out = tr.fit(params, data, steps=12)
    losses = [h["loss"] for h in out["history"]]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses
