"""Per-architecture smoke tests: reduced config, one forward/train-vjp and
one prefill+decode step on CPU — shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.lm import LM


def _batch(cfg, key, b=2, t=16):
    ks = jax.random.split(key, 2)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab_size),
    }
    if cfg.frontend == "patch":
        batch["images"] = jax.random.normal(
            key, (b, cfg.image_size, cfg.image_size, cfg.image_channels))
    if cfg.frontend == "audio":
        batch["mels"] = jax.random.normal(
            key, (b, 2 * cfg.encoder_seq, cfg.n_mels))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = get_reduced_config(arch)
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key)
    b, t = 2, 16
    batch = _batch(cfg, key, b, t)
    rng = jax.random.PRNGKey(1)

    # ---- train forward + both backward passes (grads + sampled stats) ----
    shapes = lm.probe_shapes(jax.eval_shape(lambda x: x, batch))
    probes = lm.make_probes(shapes)

    def f(p, pr):
        (lt, ls), aux = lm.loss(p, pr, batch, rng, mode="collect")
        return (lt, ls), aux["recs"]

    (lt, ls), vjp_fn, recs = jax.vjp(f, params, probes, has_aux=True)
    assert jnp.isfinite(lt) and jnp.isfinite(ls), arch
    grads, _ = vjp_fn((jnp.float32(1.0), jnp.float32(0.0)))
    _, gprobes = vjp_fn((jnp.float32(0.0), jnp.float32(1.0)))
    for leaf in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(leaf)), arch
    # every meta has its records / cotangents
    for name, meta in lm.metas.items():
        if meta.kind == "head":
            assert name in recs
        else:
            assert name in recs, (arch, name)
            if meta.kind != "head":
                assert name in gprobes or meta.kind == "head"

    # ---- prefill + one decode step (serve path) ----
    logits, cache = lm.prefill(params, batch)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), arch
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, cache2 = lm.decode_step(params, cache, tok, jnp.int32(t))
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2)), arch


def test_decode_matches_full_forward():
    """Teacher-forced decode logits == full-forward logits (KV-cache path
    consistency) for a dense arch."""
    cfg = get_reduced_config("llama3.2-1b")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    b, t = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    # full forward logits at last position
    logits_full, cache = lm.prefill(params, batch)

    # decode path: prefill on t-1 tokens then one decode step
    batch2 = {"tokens": toks[:, :-1], "labels": toks[:, :-1]}
    _, cache2 = lm.prefill(params, batch2)
    # pad the cache to length t
    def pad(x):
        if x.ndim >= 3 and x.shape[2] == t - 1:
            pad_shape = list(x.shape)
            pad_shape[2] = 1
            return jnp.concatenate([x, jnp.zeros(pad_shape, x.dtype)], axis=2)
        return x
    cache2 = jax.tree.map(pad, cache2)
    logits_dec, _ = lm.decode_step(params, cache2, toks[:, -1:],
                                   jnp.int32(t - 1))
    assert jnp.allclose(logits_full[:, -1], logits_dec[:, -1], atol=2e-2), (
        jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, -1])))
