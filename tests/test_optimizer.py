"""K-FAC optimizer behaviour: beats tuned SGD+momentum per-iteration on the
paper's own problem family; all schedule paths execute."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import KFACConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.lm import LM
from repro.models.mlp import MLP


def _ae_setup(inv_mode="blkdiag", steps=25):
    mlp = MLP([32, 24, 12, 24, 32], nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(32, 6, 512, seed=7)
    batch = data.batch(0)
    cfg = KFACConfig(inv_mode=inv_mode, inverse_method="eigh",
                     lambda_init=1.0, t3=5, eta=1e-5)
    opt = KFAC(mlp, cfg, family="bernoulli")
    state = opt.init(params, batch)
    stats = jax.jit(opt.stats_grads)
    refresh = jax.jit(opt.refresh_inverses)
    update = jax.jit(lambda s, p, g, b, r: opt.apply_update(s, p, g, b, r))
    lam = jax.jit(opt.lambda_step)
    losses = []
    for step in range(steps):
        rng = jax.random.PRNGKey(100 + step)
        state, grads, metr = stats(state, params, batch, rng)
        if step % cfg.t3 == 0 or step < 3:
            state = refresh(state)
        params, state, um = update(state, params, grads, batch, rng)
        if (step + 1) % cfg.t1 == 0:
            state, _ = lam(state, params, batch, rng)
        losses.append(float(metr["loss"]))
    return losses, params, state


def _sgd_momentum(steps=25, lr=0.1, mom=0.9):
    mlp = MLP([32, 24, 12, 24, 32], nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(32, 6, 512, seed=7)
    batch = data.batch(0)

    def loss_fn(p):
        (lt, _), _ = mlp.loss(p, None, batch, jax.random.PRNGKey(0), "plain")
        return lt

    gfn = jax.jit(jax.grad(loss_fn))
    lfn = jax.jit(loss_fn)
    vel = jax.tree.map(jnp.zeros_like, params)
    losses = []
    for _ in range(steps):
        g = gfn(params)
        vel = jax.tree.map(lambda v, gg: mom * v - lr * gg, vel, g)
        params = jax.tree.map(lambda p, v: p + v, params, vel)
        losses.append(float(lfn(params)))
    return losses


def test_kfac_beats_sgd_per_iteration():
    """The paper's headline claim, at miniature scale."""
    kfac_losses, _, _ = _ae_setup("blkdiag", steps=25)
    sgd_losses = _sgd_momentum(steps=25)
    assert kfac_losses[-1] < kfac_losses[0]
    assert kfac_losses[-1] < sgd_losses[-1], (kfac_losses[-1], sgd_losses[-1])


def test_tridiag_runs_and_descends():
    losses, _, _ = _ae_setup("tridiag", steps=15)
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_gamma_candidate_selection():
    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(16, 4, 128, seed=3)
    batch = data.batch(0)
    cfg = KFACConfig(lambda_init=1.0, t3=1)
    opt = KFAC(mlp, cfg, family="bernoulli")
    state = opt.init(params, batch)
    rng = jax.random.PRNGKey(5)
    state, grads, _ = opt.stats_grads(state, params, batch, rng)
    gammas, inv3 = opt.refresh_multi(state)
    cand = [jax.tree.map(lambda x: x[c], inv3) for c in range(3)]
    params2, state2, um = opt.apply_update(state, params, grads, batch, rng,
                                           cand_inv=cand, gammas=gammas)
    assert float(state2["gamma"]) in [float(g) for g in gammas]
    assert np.isfinite(float(um["m_delta"]))
    assert float(um["m_delta"]) <= 0.0


def test_lambda_rule_direction():
    """rho > 3/4 shrinks lambda; rho < 1/4 grows it (S6.5)."""
    from repro.core.damping import lambda_update
    lam = jnp.float32(10.0)
    assert float(lambda_update(lam, 0.9, 0.8)) < 10.0
    assert float(lambda_update(lam, 0.1, 0.8)) > 10.0
    assert float(lambda_update(lam, 0.5, 0.8)) == 10.0


def test_momentum_improves_quadratic_model():
    """With momentum, selected M(delta) must be <= the no-momentum M."""
    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(16, 4, 128, seed=3)
    batch = data.batch(0)
    for use_mom in (False, True):
        cfg = KFACConfig(lambda_init=1.0, use_momentum=use_mom)
        opt = KFAC(mlp, cfg, family="bernoulli")
        state = opt.init(params, batch)
        rng = jax.random.PRNGKey(5)
        # warm up momentum buffer with two steps
        p = params
        for step in range(3):
            state, grads, _ = opt.stats_grads(state, p, batch, rng)
            state = opt.refresh_inverses(state)
            p, state, um = opt.apply_update(state, p, grads, batch, rng)
        if use_mom:
            m_mom = float(um["m_delta"])
        else:
            m_plain = float(um["m_delta"])
    # both negative; momentum's 2-d subspace can only improve the model value
    assert m_mom <= 0 and m_plain <= 0


def test_kfac_on_reduced_lm_moe():
    cfg = get_reduced_config("granite-moe-1b-a400m")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    kcfg = KFACConfig(lambda_init=10.0, t3=2)
    opt = KFAC(lm, kcfg)
    state = opt.init(params, batch)
    losses = []
    for step in range(4):
        rng = jax.random.PRNGKey(100 + step)
        state, grads, metr = opt.stats_grads(state, params, batch, rng)
        if step % 2 == 0:
            state = opt.refresh_inverses(state)
        params, state, _ = opt.apply_update(state, params, grads, batch, rng)
        losses.append(float(metr["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] + 0.1


@pytest.mark.slow
@pytest.mark.parametrize("arch,key", [("whisper-small", "mels"),
                                      ("phi-3-vision-4.2b", "images")])
def test_kfac_on_conv_frontend_archs(arch, key):
    """Acceptance: whisper / phi3-vision train end-to-end with their REAL
    conv frontends — the stem parameters are inside Kronecker blocks
    (kind="conv", ConvKronecker), accumulate patch statistics, and receive
    preconditioned (non-raw-gradient) updates."""
    from repro.core.blocks import ConvKronecker
    from repro.utils import tree as T
    cfg = get_reduced_config(arch)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if key == "images":
        batch[key] = jax.random.normal(
            jax.random.PRNGKey(4),
            (4, cfg.image_size, cfg.image_size, cfg.image_channels))
    else:
        batch[key] = jax.random.normal(
            jax.random.PRNGKey(4), (4, 2 * cfg.encoder_seq, cfg.n_mels))
    # modest damping: with lambda >> tr(factors) the damped inverse would be
    # indistinguishable from a rescale and the structure check below vacuous
    opt = KFAC(lm, KFACConfig(lambda_init=1.0, t3=2))
    conv_names = [n for n, b in opt.blocks.items()
                  if isinstance(b, ConvKronecker)]
    assert conv_names, "no conv blocks resolved — frontend still stubbed?"
    state = opt.init(params, batch)
    losses = []
    for step in range(3):
        rng = jax.random.PRNGKey(100 + step)
        state, grads, metr = opt.stats_grads(state, params, batch, rng)
        if step % 2 == 0:
            state = opt.refresh_inverses(state)
        params, state, _ = opt.apply_update(state, params, grads, batch, rng)
        losses.append(float(metr["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    # routing: the optimizer's preconditioned direction for each conv weight
    # is exactly the ConvKronecker apply — not the untagged diagonal path
    grads_reg = T.tree_axpy(opt.cfg.eta, T.tree_cast(params, jnp.float32),
                            T.tree_cast(grads, jnp.float32))
    out = opt._precondition(grads_reg, state["inv"], state)
    for n in conv_names:
        meta = opt.blocks[n].meta
        fac = state["factors"][n]
        assert fac["a"].shape[-1] == meta.a_dim
        assert float(jnp.abs(fac["a"]).max()) > 0, n      # stats accumulated
        want = -opt.blocks[n].precondition(
            state["inv"][n], T.get_path(grads_reg, meta.param_path))
        np.testing.assert_allclose(T.get_path(out, meta.param_path), want,
                                   rtol=1e-5, atol=1e-6, err_msg=n)


def test_staggered_refresh_and_stats_period():
    """Beyond-paper schedule knobs: round-robin inverse refresh covers every
    block across T3 steps; grads_only skips the stats pass but still trains."""
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticAutoencoderData
    from repro.training.trainer import Trainer

    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    cfg = KFACConfig(lambda_init=1.0, t3=3, staggered_inverse=True,
                     stats_period=2)
    opt = KFAC(mlp, cfg, family="bernoulli")
    groups = opt.stagger_groups()
    assert sum(len(g) for g in groups) == len(opt.metas)
    assert len(groups) == cfg.t3

    class Data:
        src = SyntheticAutoencoderData(16, 4, 128, seed=3)

        def batch(self, step):
            return self.src.batch(step, 128)

    tr = Trainer(mlp, opt, TrainConfig(steps=8, log_every=100), None, None)
    out = tr.fit(params, Data(), steps=8)
    losses = [h["loss"] for h in out["history"]]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
