"""The kernel autotuner (repro.kernels.autotune) and the backward-pass
fusion of the factor statistics / fixed-lr update chain.

Covers the PR's contracts:
  * cache hit/miss determinism (injectable timer, call counting),
  * a corrupted or stale on-disk cache re-tunes — never crashes,
  * ``autotune="off"`` is bitwise the untuned path,
  * fused backward factor accumulation allclose-matches the two-pass
    statistics per inv_mode (tridiag disables fusion),
  * the fused precondition+momentum+clip stage matches the three-op
    reference, and ``momentum_global_clip`` matches its chained form,
  * the ``update_chain`` kernel matches the einsum reference.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import KFACConfig
from repro.core import transform as TF
from repro.data.pipeline import SyntheticAutoencoderData
from repro.kernels import autotune as at
from repro.models.mlp import MLP
from repro.optimizers.kfac import KFACEngine
from repro.utils import tree as T

SHAPE = (256, 128)                      # factor_update problem: x (N, d)


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets its own cache file and a clean in-process memo, and
    never sees a REPRO_AUTOTUNE override from the environment."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    at.clear_memo()
    yield
    at.clear_memo()


def _counting_timer():
    calls = {"n": 0}

    def timer(fn, iters=3):
        calls["n"] += 1
        jax.block_until_ready(fn())
        return float(calls["n"])        # first legal candidate wins
    return timer, calls


# ---------------------------------------------------------------------------
# cache behaviour
# ---------------------------------------------------------------------------

def test_cache_miss_tunes_then_hits():
    timer, calls = _counting_timer()
    cfg = at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                   mode="cache", timer=timer)
    assert cfg in at.candidates("factor_update", SHAPE)
    n_tuned = calls["n"]
    assert n_tuned == len(at.candidates("factor_update", SHAPE))
    # in-process memo hit: no re-timing
    assert at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                    mode="cache", timer=timer) == cfg
    assert calls["n"] == n_tuned
    # fresh-process simulation: disk hit, still no re-timing
    at.clear_memo()
    assert at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                    mode="cache", timer=timer) == cfg
    assert calls["n"] == n_tuned


def test_cache_is_deterministic_given_timings():
    timer1, _ = _counting_timer()
    cfg1 = at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                    mode="cache", timer=timer1)
    os.remove(at.cache_path())
    at.clear_memo()
    timer2, _ = _counting_timer()
    cfg2 = at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                    mode="cache", timer=timer2)
    assert cfg1 == cfg2


def test_corrupted_cache_retunes_never_crashes():
    timer, calls = _counting_timer()
    at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
             mode="cache", timer=timer)
    n = calls["n"]
    with open(at.cache_path(), "w") as f:
        f.write("{this is not json")
    at.clear_memo()
    cfg = at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                   mode="cache", timer=timer)
    assert cfg in at.candidates("factor_update", SHAPE)
    assert calls["n"] > n                 # it re-tuned
    # and the rewritten cache is valid again
    assert at.load_cache() != {}


def test_stale_cache_entry_retunes():
    timer, calls = _counting_timer()
    key = at.cache_key("factor_update", SHAPE, jnp.float32,
                       at.backend_tag(True))
    # a winner that is no longer a legal candidate (constraints changed)
    at.save_entry(key, {"cfg": {"bm": 999, "bn": 3, "bk": 7}, "us": 1.0,
                        "timings": {}})
    cfg = at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                   mode="cache", timer=timer)
    assert cfg in at.candidates("factor_update", SHAPE)
    assert calls["n"] > 0


def test_env_override_wins(monkeypatch):
    timer, calls = _counting_timer()
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                    mode="cache", timer=timer) is None
    assert calls["n"] == 0


def test_off_returns_none_and_no_candidates_is_none():
    assert at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                    mode="off") is None
    # ragged problem: no legal candidate -> None (caller falls back)
    timer, _ = _counting_timer()
    assert at.tuned("precond", (100, 37), jnp.float32, interpret=True,
                    mode="cache", timer=timer) is None


def test_autotune_off_is_bitwise_untuned():
    """autotune="off" feeds the kernel its built-in default blocks — the
    exact same program as before the autotuner existed."""
    from repro.kernels.factor_update import factor_update
    x = jax.random.normal(jax.random.PRNGKey(0), SHAPE, jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (SHAPE[1], SHAPE[1]))
    cfg = at.tuned("factor_update", SHAPE, jnp.float32, interpret=True,
                   mode="off") or {}
    assert cfg == {}
    out_off = factor_update(x, c, alpha=0.1, beta=0.9, interpret=True, **cfg)
    out_ref = factor_update(x, c, alpha=0.1, beta=0.9, interpret=True)
    assert np.array_equal(np.asarray(out_off), np.asarray(out_ref))


def test_tuned_config_changes_tiles_not_results():
    from repro.kernels.factor_update import factor_update
    x = jax.random.normal(jax.random.PRNGKey(0), SHAPE, jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (SHAPE[1], SHAPE[1]))
    ref = factor_update(x, c, alpha=0.1, beta=0.9, interpret=True)
    for cfg in at.candidates("factor_update", SHAPE):
        out = factor_update(x, c, alpha=0.1, beta=0.9, interpret=True, **cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# update_chain kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128), (256, 128)])
def test_update_chain_kernel_matches_reference(shape):
    from repro.kernels.update_chain import precond_momentum
    d_in, d_out = shape
    k = jax.random.PRNGKey(0)
    a = jax.random.normal(k, (d_in, d_in))
    v = jax.random.normal(jax.random.fold_in(k, 1), (d_in, d_out))
    g = jax.random.normal(jax.random.fold_in(k, 2), (d_out, d_out))
    m = jax.random.normal(jax.random.fold_in(k, 3), (d_in, d_out))
    alpha, mu = jnp.float32(-0.05), jnp.float32(0.9)
    d, sq = precond_momentum(a, v, g, m, alpha=alpha, mu=mu, interpret=True)
    ref = alpha * (a @ v @ g) + mu * m
    np.testing.assert_allclose(np.asarray(d), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(sq), float(jnp.sum(ref * ref)),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# fused backward-pass statistics vs the two-pass reference
# ---------------------------------------------------------------------------

def _mlp_engine(fused, backend="xla", inv_mode="blkdiag"):
    dims = [16, 16, 8, 16, 16]
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(dims[0], 8, 256, seed=7)
    batch = data.batch(0)
    cfg = KFACConfig(kernel_backend=backend, inv_mode=inv_mode,
                     fused_stats=fused)
    return KFACEngine(mlp, cfg, family="bernoulli"), params, batch


def _run_stats(eng, params, batch, steps=3):
    state = eng.init(params, batch)
    for step in range(steps):
        rng = jax.random.PRNGKey(100 + step)
        state, _, _ = jax.jit(eng.stats_grads)(state, params, batch, rng)
    return state


@pytest.mark.parametrize("inv_mode", ["blkdiag", "eigen"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fused_stats_match_two_pass(inv_mode, backend):
    eng0, params, batch = _mlp_engine(False, backend="xla",
                                      inv_mode=inv_mode)
    eng1, _, _ = _mlp_engine(True, backend=backend, inv_mode=inv_mode)
    assert eng1.fused_names, "dense MLP layers must be fused-eligible"
    s0 = _run_stats(eng0, params, batch)
    s1 = _run_stats(eng1, params, batch)
    for name in s0.factors:
        for side in ("a", "g"):
            a = np.asarray(s0.factors[name][side])
            b = np.asarray(s1.factors[name][side])
            np.testing.assert_allclose(
                b, a, rtol=1e-5, atol=1e-6,
                err_msg=f"{inv_mode}/{backend} {name}.{side}")


def test_tridiag_disables_fusion():
    eng, _, _ = _mlp_engine(True, inv_mode="tridiag")
    assert not eng.fused and not eng.fused_names


def test_fused_probe_shape_is_tiny():
    eng, params, batch = _mlp_engine(True)
    probes = eng._probes(batch)
    for name in eng.fused_names:
        p = probes[name]
        assert isinstance(p, dict) and set(p) == {"gg"}
        g = eng.metas[name].g_dim
        assert p["gg"].shape == (g, g)


# ---------------------------------------------------------------------------
# the fused fixed-lr update chain vs the three-op reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inv_mode", ["blkdiag", "eigen", "tridiag"])
def test_fused_update_matches_three_op_reference(inv_mode):
    dims = [16, 16, 8, 16, 16]
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(dims[0], 8, 256, seed=7)
    batch = data.batch(0)
    cfg = KFACConfig(inv_mode=inv_mode, use_rescale=False, fixed_lr=0.05,
                     fixed_momentum=0.9, clip_delta_norm=1e-3)
    eng = KFACEngine(mlp, cfg, family="bernoulli")
    state = eng.init(params, batch)
    rng = jax.random.PRNGKey(7)
    state, grads, _ = eng.stats_grads(state, params, batch, rng)
    state = eng.refresh_inverses(state)
    # nonzero velocity so the momentum term and the clip both bite
    state = state.replace(delta0=jax.tree.map(
        lambda d: 0.01 * jax.random.normal(jax.random.PRNGKey(9),
                                           d.shape, d.dtype), state.delta0))

    p_fused, s_fused, m = eng.apply_update_fused(state, params, grads,
                                                 batch, rng)

    # reference: precondition, momentum, global clip, apply — as three
    # separate ops over materialized intermediates
    grads_reg = T.tree_axpy(cfg.eta, T.tree_cast(params, jnp.float32),
                            T.tree_cast(grads, jnp.float32))
    delta = T.tree_scale(eng._precondition(grads_reg, state.inv, state),
                         cfg.fixed_lr)
    vel = jax.tree.map(lambda d, mo: d + cfg.fixed_momentum * mo,
                       delta, state.delta0)
    norm = jnp.sqrt(T.tree_sqnorm(vel))
    factor = jnp.minimum(1.0, cfg.clip_delta_norm / jnp.maximum(norm, 1e-20))
    p_ref = jax.tree.map(lambda p, d: p + (factor * d).astype(p.dtype),
                         params, vel)

    for a, b in zip(jax.tree.leaves(p_fused), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # stored velocity is pre-clip (with_momentum semantics)
    for a, b in zip(jax.tree.leaves(s_fused.delta0), jax.tree.leaves(vel)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(m["delta_norm"]), float(factor * norm),
                               rtol=1e-5)


def _pipe(opt):
    # Optimizer.update is the bound KFACPipeline.update
    return opt.update.__self__


def test_fused_stage_name_in_pipeline():
    from repro import optimizers
    dims = [16, 16, 8, 16, 16]
    fixed = optimizers.kfac(MLP(dims, nonlin="tanh", loss="bernoulli"),
                            KFACConfig(use_rescale=False),
                            family="bernoulli")
    names = [s.name for s in _pipe(fixed).stages]
    assert "fused_precondition_momentum_clip" in names
    assert "precondition+quadratic_model_lr_momentum" not in names
    quad = optimizers.kfac(MLP(dims, nonlin="tanh", loss="bernoulli"),
                           KFACConfig(), family="bernoulli")
    names = [s.name for s in _pipe(quad).stages]
    assert "precondition+quadratic_model_lr_momentum" in names


def test_momentum_global_clip_matches_chained_form():
    params = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((7,))}
    u = {"a": jax.random.normal(jax.random.PRNGKey(0), (3, 4)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (7,))}
    fused = TF.momentum_global_clip(0.9, 0.5)
    ref = TF.chain(TF.with_momentum(0.9), TF.clip_by_global_norm(0.5))
    sf, sr = fused.init(params), ref.init(params)
    for i in range(5):
        uf, sf = fused.update(u, sf, params)
        ur, sr = ref.update(u, sr, params)
        for k in u:
            np.testing.assert_allclose(np.asarray(uf[k]), np.asarray(ur[k]),
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=f"step {i} leaf {k}")


def test_bad_autotune_mode_rejected():
    dims = [16, 16, 8, 16, 16]
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    with pytest.raises(ValueError, match="autotune"):
        KFACEngine(mlp, KFACConfig(autotune="sometimes"),
                   family="bernoulli")


# ---------------------------------------------------------------------------
# paged flash-decode tuning: legal head blocks, every candidate allclose
# ---------------------------------------------------------------------------

PAGED_SHAPE = (2, 8, 2, 32, 3, 8)       # (b, hq, hkv, hd, max_blocks, page)


def test_paged_decode_candidates_legal():
    b, hq, hkv, hd, nb, page = PAGED_SHAPE
    group = hq // hkv
    cands = at.candidates("flash_decode_paged", PAGED_SHAPE)
    assert cands and {"bh": 1} in cands
    for cfg in cands:
        assert set(cfg) == {"bh"}
        assert cfg["bh"] <= group and group % cfg["bh"] == 0
    # ragged head dim / non-GQA head counts: no legal candidates
    assert at.candidates("flash_decode_paged", (2, 8, 2, 33, 3, 8)) == []
    assert at.candidates("flash_decode_paged", (2, 7, 2, 32, 3, 8)) == []


def test_paged_decode_every_candidate_allclose():
    """Each legal head block is the same kernel numerically — vs the
    dense-gather einsum oracle, not just vs another bh."""
    from repro.kernels import ops
    from repro.kernels.flash_decode import flash_decode_paged
    b, hq, hkv, hd, nb, page = PAGED_SHAPE
    num_pages = 1 + b * nb
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, hq, hd), jnp.float32)
    kp = jax.random.normal(jax.random.fold_in(k, 1),
                           (num_pages, page, hkv, hd), jnp.float32)
    vp = jax.random.normal(jax.random.fold_in(k, 2),
                           (num_pages, page, hkv, hd), jnp.float32)
    pt = jax.random.permutation(jax.random.fold_in(k, 3),
                                jnp.arange(1, num_pages)).reshape(b, nb)
    lengths = jnp.asarray([page + 3, nb * page], jnp.int32)
    kd, vd = ops.paged_gather(kp, vp, pt)
    want = ops.flash_decode_ref(q, kd, vd, lengths, window=5, cap=30.0)
    for cfg in at.candidates("flash_decode_paged", PAGED_SHAPE):
        out = flash_decode_paged(q, kp, vp, lengths, pt, window=5, cap=30.0,
                                 interpret=True, **cfg)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5,
                                   err_msg=f"cfg={cfg}")


def test_paged_decode_tunes_and_caches():
    timer, calls = _counting_timer()
    cfg = at.tuned("flash_decode_paged", PAGED_SHAPE, jnp.bfloat16,
                   interpret=True, mode="cache", timer=timer)
    cands = at.candidates("flash_decode_paged", PAGED_SHAPE)
    assert cfg in cands
    assert calls["n"] == len(cands)
    at.clear_memo()                      # fresh process: disk hit
    assert at.tuned("flash_decode_paged", PAGED_SHAPE, jnp.bfloat16,
                    interpret=True, mode="cache", timer=timer) == cfg
    assert calls["n"] == len(cands)
