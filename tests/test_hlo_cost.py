"""The trip-count-aware HLO cost analyzer vs known-cost programs."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_multiplied():
    def body(c, x):
        return c @ x, ()

    w = jnp.zeros((64, 64))
    xs = jnp.zeros((7, 64, 64))
    txt = _compile_text(lambda w, xs: jax.lax.scan(body, w, xs)[0], w, xs)
    res = hlo_cost.analyze(txt)
    want = 7 * 2 * 64 ** 3
    assert abs(res["flops"] - want) < 0.1 * want, res["flops"]


def test_nested_scan():
    def inner(c, x):
        return c @ x, ()

    xs = jnp.zeros((5, 32, 32))

    def outer(c, _):
        c2, _ = jax.lax.scan(inner, c, xs)
        return c2, ()

    w = jnp.zeros((32, 32))
    txt = _compile_text(
        lambda w: jax.lax.scan(outer, w, jnp.zeros((3, 1)))[0], w)
    res = hlo_cost.analyze(txt)
    want = 15 * 2 * 32 ** 3
    assert abs(res["flops"] - want) < 0.15 * want, res["flops"]


def test_dot_flops_exact():
    a = jnp.zeros((128, 256))
    b = jnp.zeros((256, 64))
    txt = _compile_text(lambda a, b: a @ b, a, b)
    res = hlo_cost.analyze(txt)
    want = 2 * 128 * 256 * 64
    assert abs(res["flops"] - want) <= 0.05 * want


def test_shape_bytes():
    assert hlo_cost.shape_bytes("f32[2,3]{1,0}") == 24
    assert hlo_cost.shape_bytes("bf16[10]") == 20
    assert hlo_cost.shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert hlo_cost.shape_bytes("pred[]") == 1
