"""The trip-count-aware HLO cost analyzer vs known-cost programs."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_multiplied():
    def body(c, x):
        return c @ x, ()

    w = jnp.zeros((64, 64))
    xs = jnp.zeros((7, 64, 64))
    txt = _compile_text(lambda w, xs: jax.lax.scan(body, w, xs)[0], w, xs)
    res = hlo_cost.analyze(txt)
    want = 7 * 2 * 64 ** 3
    assert abs(res["flops"] - want) < 0.1 * want, res["flops"]


def test_nested_scan():
    def inner(c, x):
        return c @ x, ()

    xs = jnp.zeros((5, 32, 32))

    def outer(c, _):
        c2, _ = jax.lax.scan(inner, c, xs)
        return c2, ()

    w = jnp.zeros((32, 32))
    txt = _compile_text(
        lambda w: jax.lax.scan(outer, w, jnp.zeros((3, 1)))[0], w)
    res = hlo_cost.analyze(txt)
    want = 15 * 2 * 32 ** 3
    assert abs(res["flops"] - want) < 0.15 * want, res["flops"]


def test_dot_flops_exact():
    a = jnp.zeros((128, 256))
    b = jnp.zeros((256, 64))
    txt = _compile_text(lambda a, b: a @ b, a, b)
    res = hlo_cost.analyze(txt)
    want = 2 * 128 * 256 * 64
    assert abs(res["flops"] - want) <= 0.05 * want


def test_shape_bytes():
    assert hlo_cost.shape_bytes("f32[2,3]{1,0}") == 24
    assert hlo_cost.shape_bytes("bf16[10]") == 20
    assert hlo_cost.shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert hlo_cost.shape_bytes("pred[]") == 1


def test_fused_update_chain_saves_bytes():
    """The fused precondition+momentum+clip stage touches fewer HLO bytes
    than the three separately-jitted ops it replaces: every stage boundary
    writes and re-reads a weight-shaped intermediate the fused program
    keeps internal (the launch/dryrun.py ``update_chain`` record, pinned
    here on a small MLP engine)."""
    from repro.configs.base import KFACConfig
    from repro.data.pipeline import SyntheticAutoencoderData
    from repro.models.mlp import MLP
    from repro.optimizers.kfac import KFACEngine
    from repro.utils import tree as T

    dims = [32, 32, 16, 32, 32]
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    batch = SyntheticAutoencoderData(dims[0], 8, 128, seed=7).batch(0)
    cfg = KFACConfig(use_rescale=False, fixed_momentum=0.9,
                     clip_delta_norm=1.0)
    eng = KFACEngine(mlp, cfg, family="bernoulli")
    state = eng.init(params, batch)
    rng = jax.random.PRNGKey(0)

    def fused_chain(state, params, grads, batch, rng):
        p, s, _ = eng.apply_update_fused(state, params, grads, batch, rng)
        return p, s.delta0

    def ref_precond(state, params, grads):
        grads_reg = T.tree_axpy(cfg.eta, T.tree_cast(params, jnp.float32),
                                T.tree_cast(grads, jnp.float32))
        return T.tree_scale(eng._precondition(grads_reg, state.inv, state),
                            cfg.fixed_lr)

    def ref_momentum(delta, state):
        return jax.tree.map(lambda d, m: d + cfg.fixed_momentum * m,
                            delta, state.delta0)

    def ref_clip_apply(vel, params):
        norm = jnp.sqrt(T.tree_sqnorm(vel))
        factor = jnp.minimum(jnp.float32(1.0),
                             cfg.clip_delta_norm / jnp.maximum(norm, 1e-20))
        return jax.tree.map(lambda p, d: p + (factor * d).astype(p.dtype),
                            params, vel)

    fused = hlo_cost.analyze(
        _compile_text(fused_chain, state, params, params, batch, rng))
    delta_abs = jax.eval_shape(ref_precond, state, params, params)
    ref_bytes = (
        hlo_cost.analyze(_compile_text(ref_precond, state, params,
                                       params))["bytes"]
        + hlo_cost.analyze(_compile_text(ref_momentum, delta_abs,
                                         state))["bytes"]
        + hlo_cost.analyze(_compile_text(ref_clip_apply, delta_abs,
                                         params))["bytes"])
    assert fused["bytes"] < ref_bytes, (fused["bytes"], ref_bytes)
