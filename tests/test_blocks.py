"""Curvature-block registry + per-block correctness vs dense references.

Each CurvatureBlock subclass's precondition is checked against the dense
``(Ā ⊗ G)⁻¹ vec(V)`` of the same damped factors, and the Pallas-routed
paths (``kernel_backend="pallas"``, interpret mode on CPU) are checked to
agree with the ``"xla"`` einsum paths to tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import KFACConfig
from repro.core import blocks as B
from repro.core import factors as F
from repro.core.tags import LayerMeta

CFG = KFACConfig()
CFG_PALLAS = CFG.replace(kernel_backend="pallas")


def _spd(key, d, scale=1.0):
    m = jax.random.normal(jax.random.PRNGKey(key), (d, d))
    return m @ m.T / d * scale + 0.1 * jnp.eye(d)


def _dense_kron_reference(block, a_dense, g_dense, gamma, v):
    """(Ā ⊗ G)⁻¹ vec(V) with the block's own factored Tikhonov damping."""
    from repro.core import inverse as INV
    m = block.meta
    pi = INV.pi_trace(a_dense, "full", m.a_dim, g_dense, "full", m.g_dim)
    a_d = a_dense + pi * gamma * jnp.eye(m.a_dim)
    g_d = g_dense + gamma / pi * jnp.eye(m.g_dim)
    f = jnp.kron(a_d, g_d)
    return (jnp.linalg.solve(f, v.reshape(-1))).reshape(m.a_dim, m.g_dim)


# ---------------------------------------------------------------------------
# registry resolution
# ---------------------------------------------------------------------------

def _meta(kind="dense", a_kind="full", g_kind="full", a_blocks=1, g_blocks=1,
          d_in=6, d_out=4, **kw):
    return LayerMeta("l", ("w",), d_in=d_in, d_out=d_out, kind=kind,
                     a_kind=a_kind, g_kind=g_kind, a_blocks=a_blocks,
                     g_blocks=g_blocks, **kw)


@pytest.mark.parametrize("meta,cls", [
    (_meta(), B.DenseKronecker),
    (_meta(a_kind="block", a_blocks=2), B.BlockDiagKronecker),
    (_meta(g_kind="block", g_blocks=2), B.BlockDiagKronecker),
    (_meta(a_kind="diag"), B.DiagFactor),
    (_meta(a_kind="diag", g_kind="block", g_blocks=2), B.DiagFactor),
    (_meta(kind="embed", a_kind="diag"), B.Embed),
    (_meta(kind="head", g_kind="diag"), B.Head),
    (_meta(kind="expert", n_expert=3), B.Expert),
])
def test_registry_resolution(meta, cls):
    assert B.resolve(meta) is cls


def test_registry_unknown_kind():
    with pytest.raises(KeyError):
        B.resolve(_meta(kind="nope"))


def test_build_blocks_covers_all_metas():
    metas = {"x": _meta(), "e": _meta(kind="embed", a_kind="diag")}
    blocks = B.build_blocks(metas, CFG)
    assert set(blocks) == {"x", "e"}
    assert isinstance(blocks["x"], B.DenseKronecker)


# ---------------------------------------------------------------------------
# per-block precondition vs the dense (Ā ⊗ G)⁻¹ reference
# ---------------------------------------------------------------------------

def test_dense_kron_block_matches_dense_reference():
    meta = _meta(d_in=6, d_out=4)
    blk = B.resolve(meta)(meta, CFG)
    a, g = _spd(0, meta.a_dim), _spd(1, meta.g_dim)
    inv = blk.damped_inverse({"a": a, "g": g}, 0.3, method="eigh")
    v = jax.random.normal(jax.random.PRNGKey(2), (meta.a_dim, meta.g_dim))
    got = blk.precondition(inv, v)
    want = _dense_kron_reference(blk, a, g, 0.3, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_blockdiag_kron_matches_dense_reference():
    """A TP-blocked Ā equals a block-diagonal dense Ā."""
    meta = _meta(d_in=8, d_out=4, a_kind="block", a_blocks=2)
    blk = B.resolve(meta)(meta, CFG)
    x = jax.random.normal(jax.random.PRNGKey(3), (32, meta.a_dim))
    a_blk = F.outer_sum(x, "block", 2) / 32
    g = _spd(4, meta.g_dim)
    inv = blk.damped_inverse({"a": a_blk, "g": g}, 0.5, method="eigh")
    v = jax.random.normal(jax.random.PRNGKey(5), (meta.a_dim, meta.g_dim))
    got = blk.precondition(inv, v)

    # dense reference with the same damping: assemble block-diagonal Ā and
    # reuse the dense meta so pi matches the blocked trace exactly
    a_dense = jnp.zeros((meta.a_dim, meta.a_dim))
    for b in range(2):
        sl = slice(b * 4, (b + 1) * 4)
        a_dense = a_dense.at[sl, sl].set(a_blk[b])
    ref_meta = _meta(d_in=8, d_out=4)
    ref = B.resolve(ref_meta)(ref_meta, CFG)
    want = _dense_kron_reference(ref, a_dense, g, 0.5, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_diag_factor_matches_dense_reference():
    meta = _meta(d_in=5, d_out=4, a_kind="diag")
    blk = B.resolve(meta)(meta, CFG)
    a_diag = jnp.abs(jax.random.normal(jax.random.PRNGKey(6),
                                       (meta.a_dim,))) + 0.5
    g = _spd(7, meta.g_dim)
    inv = blk.damped_inverse({"a": a_diag, "g": g}, 0.4, method="eigh")
    v = jax.random.normal(jax.random.PRNGKey(8), (meta.a_dim, meta.g_dim))
    got = blk.precondition(inv, v)
    ref_meta = _meta(d_in=5, d_out=4)
    ref = B.resolve(ref_meta)(ref_meta, CFG)
    want = _dense_kron_reference(ref, jnp.diag(a_diag), g, 0.4, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_embed_head_blocks_match_dense_reference():
    for kind, a_kind, g_kind in (("embed", "diag", "full"),
                                 ("head", "full", "diag")):
        meta = _meta(kind=kind, d_in=7, d_out=3, a_kind=a_kind, g_kind=g_kind)
        blk = B.resolve(meta)(meta, CFG)
        if a_kind == "diag":
            a = jnp.abs(jax.random.normal(jax.random.PRNGKey(9),
                                          (meta.a_dim,))) + 0.5
            g = _spd(10, meta.g_dim)
            a_dense, g_dense = jnp.diag(a), g
        else:
            a = _spd(11, meta.a_dim)
            g = jnp.abs(jax.random.normal(jax.random.PRNGKey(12),
                                          (meta.g_dim,))) + 0.5
            a_dense, g_dense = a, jnp.diag(g)
        inv = blk.damped_inverse({"a": a, "g": g}, 0.2, method="eigh")
        v = jax.random.normal(jax.random.PRNGKey(13),
                              (meta.a_dim, meta.g_dim))
        got = blk.precondition(inv, v)
        ref_meta = _meta(d_in=7, d_out=3)
        ref = B.resolve(ref_meta)(ref_meta, CFG)
        want = _dense_kron_reference(ref, a_dense, g_dense, 0.2, v)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5,
                                   err_msg=kind)


def test_expert_block_matches_per_expert_dense():
    ne = 3
    meta = _meta(kind="expert", d_in=5, d_out=4, n_expert=ne)
    blk = B.resolve(meta)(meta, CFG)
    a = jnp.stack([_spd(20 + e, meta.a_dim) for e in range(ne)])
    g = jnp.stack([_spd(30 + e, meta.g_dim) for e in range(ne)])
    inv = blk.damped_inverse({"a": a, "g": g}, 0.3, method="eigh")
    v = jax.random.normal(jax.random.PRNGKey(14),
                          (ne, meta.a_dim, meta.g_dim))
    got = blk.precondition(inv, v)
    ref_meta = _meta(d_in=5, d_out=4)
    ref = B.resolve(ref_meta)(ref_meta, CFG)
    for e in range(ne):
        want = _dense_kron_reference(ref, a[e], g[e], 0.3, v[e])
        np.testing.assert_allclose(got[e], want, rtol=1e-4, atol=1e-5,
                                   err_msg=f"expert {e}")


# ---------------------------------------------------------------------------
# kernel_backend="pallas" (interpret) vs "xla" agreement
# ---------------------------------------------------------------------------

def test_dense_update_factors_pallas_matches_xla():
    meta = _meta(d_in=64, d_out=32)
    n = 128
    a_raw = jax.random.normal(jax.random.PRNGKey(15), (n, meta.a_dim))
    cot = jax.random.normal(jax.random.PRNGKey(16), (n, meta.g_dim)) / n
    old = {"a": _spd(17, meta.a_dim), "g": _spd(18, meta.g_dim)}
    rec = {"a": a_raw}

    out = {}
    for label, cfg in (("xla", CFG), ("pallas", CFG_PALLAS)):
        blk = B.resolve(meta)(meta, cfg)
        # eps traced through jit, like the optimizer's decayed blend
        fn = jax.jit(lambda eps, b=blk: b.update_factors(
            old, rec, cot, {}, n, eps))
        out[label] = fn(jnp.float32(0.9))
    np.testing.assert_allclose(out["pallas"]["a"], out["xla"]["a"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(out["pallas"]["g"], out["xla"]["g"],
                               rtol=1e-4, atol=1e-5)


def test_dense_update_factors_pallas_ragged_falls_back():
    """Non-tileable dims must still produce the einsum-path result."""
    meta = _meta(d_in=13, d_out=9)       # ragged: no 8-alignment
    n = 21
    rec = {"a": jax.random.normal(jax.random.PRNGKey(19), (n, meta.a_dim))}
    cot = jax.random.normal(jax.random.PRNGKey(20), (n, meta.g_dim)) / n
    old = {"a": _spd(21, meta.a_dim), "g": _spd(22, meta.g_dim)}
    blk_x = B.resolve(meta)(meta, CFG)
    blk_p = B.resolve(meta)(meta, CFG_PALLAS)
    want = blk_x.update_factors(old, rec, cot, {}, n, jnp.float32(0.8))
    got = blk_p.update_factors(old, rec, cot, {}, n, jnp.float32(0.8))
    np.testing.assert_allclose(got["a"], want["a"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["g"], want["g"], rtol=1e-5, atol=1e-6)


def test_dense_precondition_pallas_matches_xla():
    meta = _meta(d_in=64, d_out=32)
    a, g = _spd(23, meta.a_dim), _spd(24, meta.g_dim)
    v = jax.random.normal(jax.random.PRNGKey(25), (meta.a_dim, meta.g_dim))
    blk_x = B.resolve(meta)(meta, CFG)
    blk_p = B.resolve(meta)(meta, CFG_PALLAS)
    inv = blk_x.damped_inverse({"a": a, "g": g}, 0.3, method="eigh")
    want = blk_x.precondition(inv, v)
    got = jax.jit(blk_p.precondition)(inv, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_dense_precondition_pallas_stacked_vmaps():
    """Scan-stacked layers route through the kernel via vmap."""
    ns = 3
    meta = _meta(d_in=32, d_out=16, n_stack=ns)
    a = jnp.stack([_spd(40 + i, meta.a_dim) for i in range(ns)])
    g = jnp.stack([_spd(50 + i, meta.g_dim) for i in range(ns)])
    v = jax.random.normal(jax.random.PRNGKey(26),
                          (ns, meta.a_dim, meta.g_dim))
    blk_x = B.resolve(meta)(meta, CFG)
    blk_p = B.resolve(meta)(meta, CFG_PALLAS)
    inv = blk_x.damped_inverse({"a": a, "g": g}, 0.4, method="eigh")
    want = blk_x.precondition(inv, v)
    got = blk_p.precondition(inv, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# eigen (EKFAC) path: eigen_state right after a refresh must reproduce the
# eigh damped-inverse apply; rotate_rescale kernel must match the einsum path
# ---------------------------------------------------------------------------

CFG_EIGEN = KFACConfig(inv_mode="eigen")


def _eigen_factors(meta, seed):
    """SPD/positive factors matching the meta's per-side kinds."""
    def one(dim, kind, blocks, s):
        if kind == "diag":
            return jnp.abs(jax.random.normal(jax.random.PRNGKey(s),
                                             (dim,))) + 0.5
        if kind == "block":
            x = jax.random.normal(jax.random.PRNGKey(s), (4 * dim, dim))
            return F.outer_sum(x, "block", blocks) / (4 * dim)
        if meta.n_expert:
            return jnp.stack([_spd(s + e, dim) for e in range(meta.n_expert)])
        return _spd(s, dim)

    return {"a": one(meta.a_dim, meta.a_kind, meta.a_blocks, seed),
            "g": one(meta.g_dim, meta.g_kind, meta.g_blocks, seed + 1)}


@pytest.mark.parametrize("meta", [
    _meta(d_in=6, d_out=4),
    _meta(d_in=8, d_out=4, a_kind="block", a_blocks=2),
    _meta(d_in=5, d_out=4, a_kind="diag"),
    _meta(kind="embed", d_in=7, d_out=3, a_kind="diag"),
    _meta(kind="head", d_in=6, d_out=3, g_kind="diag"),
    _meta(kind="expert", d_in=5, d_out=4, n_expert=3),
], ids=["dense", "blockdiag", "diagfactor", "embed", "head", "expert"])
def test_eigen_state_matches_eigh_preconditioner(meta):
    """With s initialized from the exact factor eigenvalues (what
    eigen_state does at refresh), the eigenbasis apply IS the eigh path."""
    blk = B.resolve(meta)(meta, CFG_EIGEN)
    fac = _eigen_factors(meta, 60)
    gamma = 0.3
    inv = blk.damped_inverse(fac, gamma, method="eigh")
    eig = blk.eigen_state(fac, gamma)
    shape = ((meta.n_expert,) if meta.n_expert else ()) + (meta.a_dim,
                                                           meta.g_dim)
    v = jax.random.normal(jax.random.PRNGKey(62), shape)
    np.testing.assert_allclose(blk.precondition_eigen(eig, v),
                               blk.precondition(inv, v),
                               rtol=1e-4, atol=1e-4)


def test_eigen_rescale_tracks_rotated_gradient():
    """eps=0 replaces s with the squared eigenbasis-rotated gradient; the
    blend interpolates linearly in between."""
    from repro.core import inverse as INV
    meta = _meta(d_in=6, d_out=4)
    blk = B.resolve(meta)(meta, CFG_EIGEN)
    eig = blk.eigen_state(_eigen_factors(meta, 70), 0.2)
    g = jax.random.normal(jax.random.PRNGKey(71), (meta.a_dim, meta.g_dim))
    t2 = jnp.square(INV.rotate_eigen(meta, eig["qa"], eig["qg"], g,
                                     adjoint=True))
    e0 = blk.rescale_step(eig, g, jnp.float32(0.0))
    np.testing.assert_allclose(e0["s"], t2, rtol=1e-5, atol=1e-6)
    e_half = blk.rescale_step(eig, g, jnp.float32(0.5))
    np.testing.assert_allclose(e_half["s"], 0.5 * eig["s"] + 0.5 * t2,
                               rtol=1e-5, atol=1e-6)
    # bases and the amortized damping are untouched by the per-step update
    np.testing.assert_allclose(e0["qa"], eig["qa"])
    np.testing.assert_allclose(e0["damp"], eig["damp"])


def test_eigen_rotation_is_orthogonal():
    """Rotating in and straight back out is the identity (Q orthonormal)."""
    from repro.core import inverse as INV
    meta = _meta(d_in=8, d_out=4, a_kind="block", a_blocks=2)
    blk = B.resolve(meta)(meta, CFG_EIGEN)
    eig = blk.eigen_state(_eigen_factors(meta, 80), 0.1)
    v = jax.random.normal(jax.random.PRNGKey(81), (meta.a_dim, meta.g_dim))
    t = INV.rotate_eigen(meta, eig["qa"], eig["qg"], v, adjoint=True)
    back = INV.rotate_eigen(meta, eig["qa"], eig["qg"], t, adjoint=False)
    np.testing.assert_allclose(back, v, rtol=1e-5, atol=1e-5)


def test_rotate_rescale_pallas_matches_xla():
    """Acceptance: pallas vs xla eigen apply agree to <= 1e-5."""
    meta = _meta(d_in=64, d_out=32)
    blk_x = B.resolve(meta)(meta, CFG_EIGEN)
    blk_p = B.resolve(meta)(meta, CFG_EIGEN.replace(kernel_backend="pallas"))
    eig = blk_x.eigen_state(_eigen_factors(meta, 90), 0.3)
    v = jax.random.normal(jax.random.PRNGKey(91), (meta.a_dim, meta.g_dim))
    want = blk_x.precondition_eigen(eig, v)
    got = jax.jit(blk_p.precondition_eigen)(eig, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rotate_rescale_pallas_stacked_vmaps():
    ns = 3
    meta = _meta(d_in=32, d_out=16, n_stack=ns)
    blk_x = B.resolve(meta)(meta, CFG_EIGEN)
    blk_p = B.resolve(meta)(meta, CFG_EIGEN.replace(kernel_backend="pallas"))
    a = jnp.stack([_spd(100 + i, meta.a_dim) for i in range(ns)])
    g = jnp.stack([_spd(110 + i, meta.g_dim) for i in range(ns)])
    eig = blk_x.eigen_state({"a": a, "g": g}, 0.4)
    v = jax.random.normal(jax.random.PRNGKey(112),
                          (ns, meta.a_dim, meta.g_dim))
    want = blk_x.precondition_eigen(eig, v)
    got = blk_p.precondition_eigen(eig, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rotate_rescale_pallas_ragged_falls_back():
    meta = _meta(d_in=13, d_out=9)        # no 8-alignment: einsum fallback
    blk_x = B.resolve(meta)(meta, CFG_EIGEN)
    blk_p = B.resolve(meta)(meta, CFG_EIGEN.replace(kernel_backend="pallas"))
    eig = blk_x.eigen_state(_eigen_factors(meta, 120), 0.2)
    v = jax.random.normal(jax.random.PRNGKey(121), (meta.a_dim, meta.g_dim))
    np.testing.assert_allclose(blk_p.precondition_eigen(eig, v),
                               blk_x.precondition_eigen(eig, v),
                               rtol=1e-6, atol=1e-7)


def test_kfac_eigen_step_end_to_end():
    """inv_mode="eigen" runs the full stats -> refresh -> rescale -> update
    cycle and the first post-refresh update matches inv_mode="blkdiag" with
    method="eigh" (identical preconditioner before any diagonal blending)."""
    from repro.core.kfac import KFAC
    from repro.models.mlp import MLP

    dims = [8, 16, 8]
    mlp = MLP(dims, loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    x = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (64, dims[0])
                             ).astype(jnp.float32)
    batch = {"x": x, "y": x}
    rng = jax.random.PRNGKey(2)

    results = {}
    for mode in ("blkdiag", "eigen"):
        cfg = KFACConfig(inv_mode=mode, inverse_method="eigh", t1=0, t2=0)
        opt = KFAC(mlp, cfg)
        state = opt.init(params, batch)
        state, grads, _ = jax.jit(opt.stats_grads)(state, params, batch, rng)
        state = jax.jit(opt.refresh_inverses)(state)
        new_params, state, _ = jax.jit(opt.apply_update)(
            state, params, grads, batch, rng)
        results[mode] = new_params
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4),
        results["eigen"], results["blkdiag"])


# ---------------------------------------------------------------------------
# ConvKronecker (KFC, 1602.01407): registry, dense-reference correctness,
# pallas==xla parity for the patch factor-update and precondition routes
# ---------------------------------------------------------------------------

def _conv_meta(c=8, k=3, stride=1, d_out=4, pad="SAME", bias=True, nd=1):
    from repro.models.conv import conv_meta
    return conv_meta("c", ("w",), spatial=(k,) * nd, stride=(stride,) * nd,
                     c_in=c, d_out=d_out, padding=pad, bias=bias)


def test_registry_resolves_conv():
    assert B.resolve(_conv_meta()) is B.ConvKronecker
    assert B.resolve(_conv_meta(nd=2)) is B.ConvKronecker


def test_conv_block_matches_dense_reference():
    """A ConvKronecker block's damped precondition equals the dense
    (Ā ⊗ G)⁻¹ reference on factors built from real patch statistics."""
    meta = _conv_meta(c=3, k=2, d_out=4)
    blk = B.resolve(meta)(meta, CFG)
    x = jax.random.normal(jax.random.PRNGKey(130), (4, 12, 3))
    cot = jax.random.normal(jax.random.PRNGKey(131), (4, 12, 4)) / 48
    fac = blk.stats_contrib({"cx": x}, cot, {}, 48)
    fac = {"a": fac["a"] + 0.1 * jnp.eye(meta.a_dim),
           "g": fac["g"] + 0.1 * jnp.eye(meta.g_dim)}
    inv = blk.damped_inverse(fac, 0.3, method="eigh")
    v = jax.random.normal(jax.random.PRNGKey(132), (meta.a_dim, meta.g_dim))
    got = blk.precondition(inv, v)
    ref_meta = _meta(d_in=meta.a_dim - 1, d_out=meta.g_dim, has_bias=True)
    ref = B.resolve(ref_meta)(ref_meta, CFG)
    want = _dense_kron_reference(ref, fac["a"], fac["g"], 0.3, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv_stats_match_dense_over_patches():
    """Feeding the raw input to ConvKronecker equals feeding the extracted
    (homogeneous) patches to a dense block — the KFC reduction."""
    from repro.models.conv import append_homog, extract_patches
    meta = _conv_meta(c=3, k=3, stride=2, d_out=4)
    blk = B.resolve(meta)(meta, CFG)
    x = jax.random.normal(jax.random.PRNGKey(133), (2, 15, 3))
    cot = jax.random.normal(jax.random.PRNGKey(134), (2, 8, 4)) / 16
    got = blk.stats_contrib({"cx": x}, cot, {}, 16)
    p = append_homog(extract_patches(x, (3,), (2,), "SAME"))
    dmeta = _meta(d_in=meta.a_dim, d_out=4)
    dense = B.resolve(dmeta)(dmeta, CFG)
    want = dense.stats_contrib({"a": p}, cot, {}, 16)
    np.testing.assert_allclose(got["a"], want["a"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["g"], want["g"], rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("meta,xshape", [
    (_conv_meta(c=8, k=3, stride=1), (2, 128, 8)),          # fused 1-D route
    (_conv_meta(c=16, k=3, stride=2), (2, 256, 16)),        # strided 1-D
    (_conv_meta(c=8, k=4, stride=4, pad="VALID", bias=False, nd=2),
     (2, 16, 16, 8)),                                       # 2-D patchify
    (_conv_meta(c=5, k=3, stride=1), (2, 21, 5)),           # ragged fallback
], ids=["conv1d", "conv1d_s2", "patchify2d", "ragged"])
def test_conv_update_factors_pallas_matches_xla(meta, xshape):
    rec = {"cx": jax.random.normal(jax.random.PRNGKey(135), xshape)}
    n = 64
    t_out = B.resolve(meta)(meta, CFG).patches(rec).shape[0] // xshape[0]
    cot = jax.random.normal(jax.random.PRNGKey(136),
                            (xshape[0], t_out, meta.g_dim)) / n
    old = {"a": _spd(137, meta.a_dim), "g": _spd(138, meta.g_dim)}
    out = {}
    for label, cfg in (("xla", CFG), ("pallas", CFG_PALLAS)):
        blk = B.resolve(meta)(meta, cfg)
        fn = jax.jit(lambda eps, b=blk: b.update_factors(
            old, rec, cot, {}, n, eps))
        out[label] = fn(jnp.float32(0.9))
    np.testing.assert_allclose(out["pallas"]["a"], out["xla"]["a"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(out["pallas"]["g"], out["xla"]["g"],
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("meta", [
    _conv_meta(c=16, k=2, bias=False),    # a_dim 32: kernel route
    _conv_meta(c=16, k=2, bias=True),     # a_dim 33: ragged fallback
], ids=["tiled", "ragged_bias"])
def test_conv_precondition_pallas_matches_xla(meta):
    a, g = _spd(140, meta.a_dim), _spd(141, meta.g_dim)
    v = jax.random.normal(jax.random.PRNGKey(142), (meta.a_dim, meta.g_dim))
    blk_x = B.resolve(meta)(meta, CFG)
    blk_p = B.resolve(meta)(meta, CFG_PALLAS)
    inv = blk_x.damped_inverse({"a": a, "g": g}, 0.3, method="eigh")
    want = blk_x.precondition(inv, v)
    got = jax.jit(blk_p.precondition)(inv, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # and the eigen-mode apply through rotate_rescale
    blk_xe = B.resolve(meta)(meta, CFG_EIGEN)
    blk_pe = B.resolve(meta)(meta, CFG_EIGEN.replace(kernel_backend="pallas"))
    eig = blk_xe.eigen_state({"a": a, "g": g}, 0.3)
    np.testing.assert_allclose(blk_pe.precondition_eigen(eig, v),
                               blk_xe.precondition_eigen(eig, v),
                               rtol=1e-4, atol=1e-4)


def test_kfac_rejects_unknown_inv_mode():
    from repro.core.kfac import KFAC
    from repro.models.mlp import MLP
    mlp = MLP([4, 4], loss="bernoulli")
    with pytest.raises(ValueError):
        KFAC(mlp, KFACConfig(inv_mode="spectral"))


# ---------------------------------------------------------------------------
# end-to-end: a KFAC step with kernel_backend="pallas" matches "xla"
# ---------------------------------------------------------------------------

def test_kfac_step_pallas_matches_xla():
    from repro.core.kfac import KFAC
    from repro.models.mlp import MLP

    dims = [8, 16, 8]
    mlp = MLP(dims, loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    x = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (64, dims[0])
                             ).astype(jnp.float32)
    batch = {"x": x, "y": x}
    rng = jax.random.PRNGKey(2)

    results = {}
    for backend in ("xla", "pallas"):
        cfg = KFACConfig(inv_mode="blkdiag", inverse_method="eigh", t1=0,
                         t2=0, kernel_backend=backend)
        opt = KFAC(mlp, cfg)
        state = opt.init(params, batch)
        state, grads, _ = jax.jit(opt.stats_grads)(state, params, batch, rng)
        state = jax.jit(opt.refresh_inverses)(state)
        new_params, state, _ = jax.jit(opt.apply_update)(
            state, params, grads, batch, rng)
        results[backend] = (new_params, state["factors"])

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5),
        results["pallas"], results["xla"])


def test_kfac_rejects_unknown_backend():
    from repro.core.kfac import KFAC
    from repro.models.mlp import MLP
    mlp = MLP([4, 4], loss="bernoulli")
    with pytest.raises(ValueError):
        KFAC(mlp, KFACConfig(kernel_backend="cuda"))
