"""Curvature-as-a-product: bundles, EKFAC iHVP/influence, Laplace serving.

The subsystem's acceptance pins:
  * bundle save/load roundtrip (f32 exact, bf16 within basis tolerance),
    loadable with no optimizer or engine in sight;
  * iHVP == dense ``(F + λI)^{-1} v`` against the explicit damped
    Kronecker oracle (property-tested over query vectors and query-time
    extra damping);
  * batched Pallas ``rotate_rescale`` route == einsum route on a tileable
    block;
  * LaplaceHead's closed-form logit variance == the dense quadratic form;
  * serving: ``uncertainty=True`` yields one finite variance per emitted
    token; ``uncertainty=False`` through a bundle-loaded engine is
    token-identical to an engine with no bundle at all (the regression
    pin that the uncertainty path costs nothing when unused);
  * trainer exports a checkpoint-adjacent bundle (schema-4 manifest
    pointer) that reloads into a working InfluenceEngine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import KFACConfig, TrainConfig
from repro.core.blocks import build_blocks
from repro.core.inverse import pi_trace
from repro.core.tags import LayerMeta
from repro.curvature import (CurvatureBundle, InfluenceEngine, LaplaceHead,
                             load_bundle, per_example_grads, save_bundle,
                             snapshot_bundle)
from repro.models.lm import LM
from repro.models.mlp import MLP
from repro.optimizers import kfac
from repro.serving.server import Engine, Request
from repro.utils import tree as T

DIMS = [8, 6, 4]


def _mlp_problem(seed=0, batch=32):
    mlp = MLP(DIMS, loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(seed), sparse=False)
    x = jax.random.bernoulli(jax.random.PRNGKey(seed + 1), 0.5,
                             (batch, DIMS[0])).astype(jnp.float32)
    return mlp, params, {"x": x, "y": x[:, :DIMS[-1]]}


def _train(inv_mode="blkdiag", steps=6, seed=0):
    """A few EKFAC steps -> (model, params, batch, engine, state)."""
    mlp, params, batch = _mlp_problem(seed)
    opt = kfac(mlp, KFACConfig(inv_mode=inv_mode, lambda_init=2.0, t3=3),
               family="bernoulli")
    state = opt.init(params, batch)
    for step in range(steps):
        params, state, _ = opt.update(
            None, state, params, batch,
            jax.random.fold_in(jax.random.PRNGKey(7), step))
    return mlp, params, batch, opt, state


@pytest.fixture(scope="module")
def trained():
    """blkdiag-mode training state: ``snapshot_bundle`` then computes a
    *fresh* eigen state from the running factors, so ``apply_eigen``
    equals the damped dense inverse exactly (the eigen-mode live state has
    its ``s`` blended by the per-step EKFAC rescale and is only ~1e-3
    close — the oracle test must use this fixture)."""
    return _train(inv_mode="blkdiag")


@pytest.fixture(scope="module")
def smollm():
    cfg = get_reduced_config("smollm-135m")
    lm = LM(cfg)
    return lm, lm.init_params(jax.random.PRNGKey(0)), cfg


def _identity_laplace(lm):
    """Zero factors + gamma=1 -> s=0, damp=1: variance(h) == |h|² exactly
    (the final RMS-norm makes |h|² == d_model), a closed-form end-to-end
    check of the serving variance plumbing."""
    name = "lm_head" if "lm_head" in lm.metas else "embed"
    meta = lm.metas[name]
    blk = build_blocks({name: meta}, KFACConfig())[name]
    eig = blk.eigen_state(blk.init_factors(), 1.0)
    return LaplaceHead(CurvatureBundle(
        step=0, lam=1.0, gamma=1.0, eta=0.0,
        metas={name: meta}, eigen={name: eig}))


def _reqs(cfg, spec, uncertainty=False):
    return [Request(uid=u, prompt=[(7 * u + j) % cfg.vocab_size
                                   for j in range(tp)], max_new=mn,
                    uncertainty=uncertainty)
            for u, tp, mn in spec]


# ---------------------------------------------------------------------------
# bundle roundtrip
# ---------------------------------------------------------------------------

def test_bundle_roundtrip_f32(tmp_path, trained):
    mlp, params, batch, opt, state = trained
    bundle = snapshot_bundle(opt.engine, state)
    path = str(tmp_path / "b32")
    save_bundle(bundle, path)
    got = load_bundle(path)
    assert got.schema == bundle.schema
    assert got.step == bundle.step
    assert got.block_names == bundle.block_names
    np.testing.assert_allclose(got.lam, bundle.lam)
    np.testing.assert_allclose(got.gamma, bundle.gamma)
    for name in bundle.block_names:
        assert got.metas[name] == bundle.metas[name]  # engine-free metas
        for k in ("qa", "qg", "s", "damp"):
            a, b = bundle.eigen[name].get(k), got.eigen[name].get(k)
            if a is None:
                assert b is None
            else:
                np.testing.assert_array_equal(np.asarray(a), b)
    # the loaded bundle drives an identical iHVP without any engine/model
    grads = per_example_grads(mlp, params, batch)
    g0 = jax.tree.map(lambda a: a[0], grads)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), b),
        InfluenceEngine(bundle).ihvp(g0), InfluenceEngine(got).ihvp(g0))


def test_bundle_roundtrip_bf16(tmp_path, trained):
    _, _, _, opt, state = trained
    bundle = snapshot_bundle(opt.engine, state)
    path = str(tmp_path / "b16")
    save_bundle(bundle, path, dtype="bfloat16")
    got = load_bundle(path)
    for name in bundle.block_names:
        for k in ("s", "damp"):          # curvature magnitudes stay exact
            np.testing.assert_array_equal(
                np.asarray(bundle.eigen[name][k]), got.eigen[name][k])
        for k in ("qa", "qg"):           # bases round-trip at bf16 precision
            a = bundle.eigen[name].get(k)
            if a is not None:
                np.testing.assert_allclose(np.asarray(a),
                                           got.eigen[name][k], atol=8e-3)


def test_torn_bundle_refused(tmp_path, trained):
    _, _, _, opt, state = trained
    path = str(tmp_path / "torn")
    save_bundle(snapshot_bundle(opt.engine, state), path)
    (tmp_path / "torn" / "COMMIT").unlink()
    with pytest.raises(FileNotFoundError):
        load_bundle(path)


# ---------------------------------------------------------------------------
# iHVP vs the dense damped-Kronecker oracle
# ---------------------------------------------------------------------------

def _dense_oracle(engine, state, grads, extra=0.0):
    """Explicit ``(F_i + damping)^{-1} vec(V_i)`` per block: materialize
    the damped Kronecker product and invert it."""
    out = {}
    for name, blk in engine.blocks.items():
        m = blk.meta
        fac = state.factors[name]
        a = np.asarray(fac["a"], np.float64)
        g = np.asarray(fac["g"], np.float64)
        pi = float(pi_trace(fac["a"], m.a_kind, m.a_dim,
                            fac["g"], m.g_kind, m.g_dim))
        gamma = float(state.gamma)
        f = np.kron(a + pi * gamma * np.eye(m.a_dim),
                    g + gamma / pi * np.eye(m.g_dim))
        f += extra * np.eye(f.shape[0])
        v = np.asarray(T.get_path(grads, m.param_path),
                       np.float64).reshape(-1)
        out[name] = np.linalg.solve(f, v).reshape(m.a_dim, m.g_dim)
    return out


def _random_tree(params, seed):
    leaves, treedef = jax.tree.flatten(params)
    return jax.tree.unflatten(treedef, [
        jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(seed), i), p.shape)
        for i, p in enumerate(leaves)])


@pytest.mark.parametrize("seed,extra", [(0, 0.0), (1, 0.0), (2, 0.5),
                                        (3, 3.0)])
def test_ihvp_matches_dense_oracle(trained, seed, extra):
    """Deterministic oracle pin (always runs — the hypothesis sweep below
    widens the same property when hypothesis is installed)."""
    mlp, params, batch, opt, state = trained
    eng = InfluenceEngine(snapshot_bundle(opt.engine, state),
                          extra_damping=extra)
    v = _random_tree(params, seed)
    got = eng.ihvp(v)
    want = _dense_oracle(eng, state, v, extra=extra)
    for name, blk in eng.blocks.items():
        np.testing.assert_allclose(
            np.asarray(T.get_path(got, blk.meta.param_path)),
            want[name], rtol=2e-4, atol=2e-5,
            err_msg=f"block {name} (extra_damping={extra})")


def test_ihvp_matches_dense_oracle_property(trained):
    hyp = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed")
    del hyp
    from hypothesis import given, settings, strategies as st

    mlp, params, batch, opt, state = trained
    bundle = snapshot_bundle(opt.engine, state)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.floats(min_value=0.0, max_value=5.0))
    def check(seed, extra):
        v = _random_tree(params, seed)
        eng = InfluenceEngine(bundle, extra_damping=extra)
        got = eng.ihvp(v)
        want = _dense_oracle(eng, state, v, extra=extra)
        for name, blk in eng.blocks.items():
            np.testing.assert_allclose(
                np.asarray(T.get_path(got, blk.meta.param_path)),
                want[name], rtol=2e-4, atol=2e-5,
                err_msg=f"block {name} (extra_damping={extra})")

    check()


def test_ihvp_batched_consistent_with_single(trained):
    mlp, params, batch, opt, state = trained
    eng = InfluenceEngine(snapshot_bundle(opt.engine, state))
    grads = per_example_grads(
        mlp, params, jax.tree.map(lambda x: x[:6], batch))
    stacked = eng.ihvp_batched(grads)
    for i in range(6):
        one = eng.ihvp(jax.tree.map(lambda a: a[i], grads))
        jax.tree_util.tree_map(
            lambda s, o, i=i: np.testing.assert_allclose(
                np.asarray(s[i]), np.asarray(o), rtol=1e-5, atol=1e-6),
            stacked, one)


def test_ihvp_batched_pallas_matches_xla():
    """The Pallas batched ``rotate_rescale`` route vs the einsum fallback
    on a tileable 128x128 dense block (the MLP's homogeneous a_dims never
    satisfy ``tile_ok``, so the parity claim needs a synthetic block)."""
    meta = LayerMeta(name="d128", param_path=("w",), d_in=128, d_out=128)
    a = jax.random.normal(jax.random.PRNGKey(0), (512, 128)) / 16.0
    g = jax.random.normal(jax.random.PRNGKey(1), (512, 128)) / 16.0
    fac = {"a": a.T @ a + 0.1 * jnp.eye(128),
           "g": g.T @ g + 0.1 * jnp.eye(128)}
    vs = jax.random.normal(jax.random.PRNGKey(2), (4, 128, 128))
    outs = {}
    for backend in ("xla", "pallas"):
        blk = build_blocks({"d128": meta},
                           KFACConfig(kernel_backend=backend))["d128"]
        eig = blk.eigen_state(fac, 0.1)
        outs[backend] = np.asarray(blk.ihvp_batched(eig, vs))
    np.testing.assert_allclose(outs["pallas"], outs["xla"],
                               rtol=5e-4, atol=5e-5)


# ---------------------------------------------------------------------------
# influence scores
# ---------------------------------------------------------------------------

def test_influence_scores_and_topk(trained):
    mlp, params, batch, opt, state = trained
    eng = InfluenceEngine(snapshot_bundle(opt.engine, state))
    grads = per_example_grads(mlp, params, batch)
    q = 3
    scores = np.asarray(eng.influence(
        jax.tree.map(lambda a: a[q], grads), grads))
    assert scores.shape == (batch["x"].shape[0],)
    assert np.isfinite(scores).all()
    # the query's own score is its (positive) self-influence
    si = np.asarray(eng.self_influence(grads))
    assert (si > 0).all()
    np.testing.assert_allclose(scores[q], si[q], rtol=1e-4)
    # top-k is the argsort head
    vals, idx = eng.topk(jnp.asarray(scores), 5)
    order = np.argsort(-scores)[:5]
    np.testing.assert_array_equal(np.asarray(idx), order)
    np.testing.assert_allclose(np.asarray(vals), scores[order], rtol=1e-6)


def test_extra_damping_shrinks_self_influence(trained):
    mlp, params, batch, opt, state = trained
    bundle = snapshot_bundle(opt.engine, state)
    grads = per_example_grads(
        mlp, params, jax.tree.map(lambda x: x[:4], batch))
    si0 = np.asarray(InfluenceEngine(bundle).self_influence(grads))
    si1 = np.asarray(
        InfluenceEngine(bundle, extra_damping=10.0).self_influence(grads))
    assert (si1 < si0).all()


# ---------------------------------------------------------------------------
# Laplace head
# ---------------------------------------------------------------------------

def test_laplace_variance_matches_dense_quadratic_form():
    """Tied-embed bundle (diag a over vocab, full g over d_model): the
    one-matmul closed form must equal the explicit quadratic form
    ``hᵀ (G + γ/π I)^{-1} h / (a_v + π γ)`` for every logit v."""
    V, d, gamma = 7, 5, 0.3
    meta = LayerMeta(name="embed", param_path=("emb",), d_in=V, d_out=d,
                     kind="embed", a_kind="diag", g_kind="full")
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (V,))) + 0.1
    gm = jax.random.normal(jax.random.PRNGKey(1), (32, d)) / 4.0
    fac = {"a": a, "g": gm.T @ gm + 0.05 * jnp.eye(d)}
    blk = build_blocks({"embed": meta}, KFACConfig())["embed"]
    bundle = CurvatureBundle(
        step=0, lam=gamma * gamma, gamma=gamma, eta=0.0,
        metas={"embed": meta},
        eigen={"embed": blk.eigen_state(fac, gamma)})
    h = jax.random.normal(jax.random.PRNGKey(2), (3, d))
    got = np.asarray(LaplaceHead(bundle)(h))

    pi = float(pi_trace(fac["a"], "diag", V, fac["g"], "full", d))
    ginv = np.linalg.inv(np.asarray(fac["g"], np.float64)
                         + gamma / pi * np.eye(d))
    quad = np.einsum("bi,ij,bj->b", np.asarray(h, np.float64),
                     ginv, np.asarray(h, np.float64))
    want = quad[:, None] / (np.asarray(a, np.float64)[None, :] + pi * gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4)
    assert (got > 0).all()


def test_laplace_head_requires_head_block(trained):
    _, _, _, opt, state = trained     # MLP bundle: dense blocks only
    with pytest.raises(ValueError):
        LaplaceHead(snapshot_bundle(opt.engine, state))


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def test_serving_uncertainty_per_token_variance(smollm):
    lm, params, cfg = smollm
    eng = Engine(lm, params, batch_slots=2, max_len=32,
                 laplace=_identity_laplace(lm))
    reqs = _reqs(cfg, [(0, 3, 5), (1, 5, 4), (2, 4, 6)], uncertainty=True)
    rep = eng.run(reqs)
    for r in reqs:
        assert r.done and r.error is None
        assert len(r.var) == len(r.out)          # one variance per token
        assert np.isfinite(r.var).all() and (np.asarray(r.var) > 0).all()
        # identity bundle + final RMS-norm: var == |h|² == d_model exactly
        np.testing.assert_allclose(r.var, float(cfg.d_model), rtol=1e-4)
    np.testing.assert_allclose(rep.mean_token_variance,
                               float(cfg.d_model), rtol=1e-4)


def test_serving_plain_path_unperturbed_by_bundle(smollm):
    """The acceptance pin: loading a bundle must not change
    ``uncertainty=False`` decoding at all — same tokens, and the report
    carries no variance."""
    lm, params, cfg = smollm
    spec = [(0, 3, 6), (1, 5, 4), (2, 4, 8), (3, 2, 5)]
    plain = _reqs(cfg, spec)
    rep0 = Engine(lm, params, batch_slots=2, max_len=32).run(plain)
    with_bundle = _reqs(cfg, spec)
    rep1 = Engine(lm, params, batch_slots=2, max_len=32,
                  laplace=_identity_laplace(lm)).run(with_bundle)
    for a, b in zip(plain, with_bundle):
        assert a.out == b.out, "bundle-loaded engine perturbed plain decode"
        assert b.var == []
    assert rep0.mean_token_variance is None
    assert rep1.mean_token_variance is None
    assert rep0.steps == rep1.steps


def test_serving_mixed_uncertainty_batch(smollm):
    """uncertainty=True and =False requests share a batch: variance lands
    only on the requesting one and the other still decodes the same."""
    lm, params, cfg = smollm
    solo = _reqs(cfg, [(0, 4, 6)])
    Engine(lm, params, batch_slots=2, max_len=32).run(solo)
    mixed = _reqs(cfg, [(0, 4, 6)]) + _reqs(cfg, [(1, 3, 6)],
                                            uncertainty=True)
    Engine(lm, params, batch_slots=2, max_len=32,
           laplace=_identity_laplace(lm)).run(mixed)
    assert mixed[0].out == solo[0].out
    assert mixed[0].var == []
    assert len(mixed[1].var) == len(mixed[1].out) > 0


def test_submit_rejects_uncertainty_without_bundle(smollm):
    lm, params, cfg = smollm
    eng = Engine(lm, params, batch_slots=2, max_len=32)   # no laplace
    bad = _reqs(cfg, [(0, 3, 4)], uncertainty=True)[0]
    ok = _reqs(cfg, [(1, 3, 4)])[0]
    rep = eng.run([bad, ok])
    assert bad.error is not None and "bundle" in bad.error
    assert not bad.out
    assert ok.done and ok.error is None and len(ok.out) == 4
    assert len(rep.completed) == 1


# ---------------------------------------------------------------------------
# trainer export -> checkpoint-adjacent bundle
# ---------------------------------------------------------------------------

def test_trainer_exports_checkpoint_adjacent_bundle(tmp_path):
    from repro.training.checkpoint import Checkpointer
    from repro.training.trainer import Trainer

    mlp, params, _ = _mlp_problem()

    class Data:
        def batch(self, step):
            x = jax.random.bernoulli(
                jax.random.fold_in(jax.random.PRNGKey(5), step), 0.5,
                (32, DIMS[0])).astype(jnp.float32)
            return {"x": x, "y": x[:, :DIMS[-1]]}

    opt = kfac(mlp, KFACConfig(inv_mode="eigen", lambda_init=2.0, t3=2),
               family="bernoulli")
    ck = Checkpointer(str(tmp_path), async_save=False)
    tr = Trainer(mlp, opt, TrainConfig(steps=6, checkpoint_every=3,
                                       curvature_every=3, log_every=100),
                 None, ck)
    out = tr.fit(params, Data(), steps=6, log=lambda *_: None)
    assert ck.latest_step() == 6
    path = ck.bundle_path()
    assert path is not None and path.endswith("step_00000006")
    bundle = load_bundle(path)
    assert bundle.step == 6
    assert set(bundle.block_names) == set(opt.engine.blocks)
    # the exported bundle drives influence queries with no optimizer
    data = Data()
    grads = per_example_grads(mlp, out["params"], data.batch(0))
    si = np.asarray(InfluenceEngine(bundle).self_influence(grads))
    assert np.isfinite(si).all() and (si > 0).all()
    # ... and the checkpoint itself still restores (manifest-only change)
    step, got = ck.restore({"params": params,
                            "state": opt.init(params, data.batch(0))})
    assert step == 6


def test_trainer_without_curvature_every_exports_nothing(tmp_path):
    from repro.training.checkpoint import Checkpointer
    from repro.training.trainer import Trainer

    mlp, params, _ = _mlp_problem()

    class Data:
        def batch(self, step):
            x = jax.random.bernoulli(
                jax.random.fold_in(jax.random.PRNGKey(5), step), 0.5,
                (32, DIMS[0])).astype(jnp.float32)
            return {"x": x, "y": x[:, :DIMS[-1]]}

    opt = kfac(mlp, KFACConfig(lambda_init=2.0), family="bernoulli")
    ck = Checkpointer(str(tmp_path), async_save=False)
    tr = Trainer(mlp, opt, TrainConfig(steps=4, checkpoint_every=2,
                                       log_every=100), None, ck)
    tr.fit(params, Data(), steps=4, log=lambda *_: None)
    assert ck.latest_step() == 4
    assert ck.bundle_path() is None
