"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.factor_update import factor_update
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.ns_step import ns_inverse, ns_step
from repro.kernels.precond import precondition


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    a, b = _rand(0, (m, k), dtype), _rand(1, (k, n), dtype)
    c = _rand(2, (m, n), jnp.float32)
    out = matmul(a, b, c, alpha=0.7, beta=0.3, bm=128, bn=128, bk=128)
    want = ref.matmul_ref(a, b, c, alpha=0.7, beta=0.3)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d", [(256, 128), (512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_factor_update(n, d, dtype):
    x = _rand(3, (n, d), dtype)
    c = _rand(4, (d, d), jnp.float32)
    out = factor_update(x, c, alpha=0.05, beta=0.95)
    want = ref.factor_update_ref(x, c, alpha=0.05, beta=0.95)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol)


def test_ns_step_matches_ref():
    d = 128
    m = _rand(5, (d, d), jnp.float32)
    m = m @ m.T / d + jnp.eye(d)
    x0 = jnp.eye(d) / jnp.max(jnp.sum(jnp.abs(m), -1))
    np.testing.assert_allclose(ns_step(m, x0), ref.ns_step_ref(m, x0),
                               rtol=1e-5, atol=1e-5)


def test_ns_inverse_converges():
    d = 128
    m = _rand(6, (d, d), jnp.float32)
    m = m @ m.T / d + jnp.eye(d)          # well-conditioned SPD
    inv = ns_inverse(m, iters=30)
    np.testing.assert_allclose(inv @ m, jnp.eye(d), rtol=0, atol=1e-3)


def test_precondition():
    d_in, d_out = 256, 128
    a = _rand(7, (d_in, d_in), jnp.float32)
    g = _rand(8, (d_out, d_out), jnp.float32)
    v = _rand(9, (d_in, d_out), jnp.float32)
    np.testing.assert_allclose(precondition(a, v, g),
                               ref.precondition_ref(a, v, g),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# fused im2col patch-factor kernel (KFC, 1602.01407)
# ---------------------------------------------------------------------------

def _patch_factor_ref(x, old, meta, alpha, beta):
    """Einsum oracle: explicit im2col + homogeneous coord + rank update."""
    from repro.models.conv import append_homog, extract_patches
    p = extract_patches(x, meta.conv_spatial, meta.conv_stride, meta.conv_pad)
    p = p.reshape(-1, p.shape[-1])
    if meta.has_bias:
        p = append_homog(p)
    return beta * old + alpha * p.T @ p


@pytest.mark.parametrize("b,t,c,k,stride,pad,bias", [
    (2, 128, 8, 3, 1, "SAME", True),      # whisper conv1 shape family
    (2, 256, 16, 3, 2, "SAME", True),     # whisper conv2 (stride 2)
    (1, 131, 8, 4, 1, "VALID", False),    # VALID with leftover rows
    (2, 512, 128, 3, 1, "SAME", True),    # full 128-lane channel tile
])
def test_patch_factor_kernel(b, t, c, k, stride, pad, bias):
    from repro.kernels.patch_factor import patch_factor_update
    from repro.models.conv import conv_meta
    meta = conv_meta("c", ("w",), spatial=(k,), stride=(stride,), c_in=c,
                     d_out=4, padding=pad, bias=bias)
    x = _rand(30, (b, t, c), jnp.float32)
    old = _rand(31, (meta.a_dim, meta.a_dim), jnp.float32)
    # traced alpha/beta through jit, like the optimizer's decayed blend
    got = jax.jit(lambda a, be: patch_factor_update(x, old, meta, a, be))(
        jnp.float32(0.03), jnp.float32(0.9))
    assert got is not None, "kernel unexpectedly declined a tiled shape"
    want = _patch_factor_ref(x, old, meta, 0.03, 0.9)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("c,t,k,pad", [
    (13, 128, 3, "SAME"),     # ragged channels
    (8, 21, 3, "SAME"),       # ragged output positions
    (136, 128, 3, "SAME"),    # channels over the 128-lane tile
    (8, 8, 9, "SAME"),        # taps exceed the time block (halo too short)
    (8, 2, 3, "VALID"),       # t < k: zero output positions
])
def test_patch_factor_ragged_declines(c, t, k, pad):
    """Shapes the kernel can't serve return None (never crash) — the block
    then falls back to the einsum path (parity checked in test_blocks)."""
    from repro.kernels.patch_factor import patch_factor_update
    from repro.models.conv import conv_meta
    meta = conv_meta("c", ("w",), spatial=(k,), stride=(1,), c_in=c,
                     d_out=4, padding=pad)
    x = _rand(32, (2, t, c), jnp.float32)
    old = jnp.eye(meta.a_dim)
    assert patch_factor_update(x, old, meta, 0.1, 0.9) is None


def test_patch_factor_2d_declines():
    """2-D convs decline the fused kernel (their im2col is a reshape; the
    plain factor_update kernel covers them via the block route)."""
    from repro.kernels.patch_factor import patch_factor_update
    from repro.models.conv import conv_meta
    meta = conv_meta("c", ("w",), spatial=(4, 4), stride=(4, 4), c_in=8,
                     d_out=4, padding="VALID")
    x = _rand(33, (2, 16, 16, 8), jnp.float32)
    assert patch_factor_update(x.reshape(2, 256, 8), jnp.eye(meta.a_dim),
                               meta, 0.1, 0.9) is None


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window,cap", [(True, 0, 0.0),
                                               (True, 64, 0.0),
                                               (True, 0, 30.0),
                                               (False, 0, 0.0)])
def test_flash_attention(hq, hkv, causal, window, cap):
    b, tq, tk, hd = 2, 128, 128, 64
    q = _rand(10, (b, hq, tq, hd), jnp.float32)
    k = _rand(11, (b, hkv, tk, hd), jnp.float32)
    v = _rand(12, (b, hkv, tk, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                          bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    b, hq, hkv, t, hd = 1, 2, 1, 128, 64
    q = _rand(13, (b, hq, t, hd), jnp.bfloat16)
    k = _rand(14, (b, hkv, t, hd), jnp.bfloat16)
    v = _rand(15, (b, hkv, t, hd), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=0.05, atol=0.05)


@pytest.mark.parametrize("length", [1, 100, 512])
def test_flash_decode(length):
    from repro.kernels.flash_decode import flash_decode
    b, hq, hkv, s, hd = 2, 4, 2, 512, 64
    q = _rand(20, (b, hq, hd), jnp.float32)
    k = _rand(21, (b, hkv, s, hd), jnp.float32)
    v = _rand(22, (b, hkv, s, hd), jnp.float32)
    out = flash_decode(q, k, v, length, bk=128)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    sc = jnp.einsum("bhgd,bhsd->bhgs", qg, k) / np.sqrt(hd)
    sc = jnp.where(jnp.arange(s) < length, sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    want = jnp.einsum("bhgs,bhsd->bhgd", p, v).reshape(b, hq, hd)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,cap", [(0, 0.0), (130, 0.0), (0, 30.0),
                                        (96, 50.0)])
def test_flash_decode_per_row_lengths(window, cap):
    """(B,) length vector + sliding window + softcap vs the einsum oracle."""
    from repro.kernels.flash_decode import flash_decode
    b, hq, hkv, s, hd = 4, 4, 2, 384, 64
    q = _rand(30, (b, hq, hd), jnp.float32)
    k = _rand(31, (b, hkv, s, hd), jnp.float32)
    v = _rand(32, (b, hkv, s, hd), jnp.float32)
    lengths = jnp.asarray([1, 77, 200, 384], jnp.int32)
    out = flash_decode(q, k, v, lengths, bk=128, window=window, cap=cap)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    sc = jnp.einsum("bhgd,bhsd->bhgs", qg, k) / np.sqrt(hd)
    if cap:
        sc = cap * jnp.tanh(sc / cap)
    pos = jnp.arange(s)
    valid = pos[None, :] < lengths[:, None]
    if window:
        valid &= pos[None, :] >= (lengths - window)[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    want = jnp.einsum("bhgs,bhsd->bhgd", p, v).reshape(b, hq, hd)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)
    # the ops-level wrapper (einsum fallback on CPU) must agree too
    from repro.kernels import ops
    out2 = ops.flash_decode(q, k, v, lengths, window=window, cap=cap)
    np.testing.assert_allclose(out2, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged flash decode: differential parity vs the dense-gather einsum oracle
# ---------------------------------------------------------------------------

def _paged_case(page, nb, b=4, hq=4, hkv=2, hd=32):
    """Random pools + a shuffled non-contiguous page assignment (what the
    free list actually hands out after reuse) + ragged per-row lengths that
    straddle page boundaries (1, exactly one page, one past, mid-page)."""
    num_pages = 1 + b * nb
    q = _rand(40, (b, hq, hd), jnp.float32)
    kp = _rand(41, (num_pages, page, hkv, hd), jnp.float32)
    vp = _rand(42, (num_pages, page, hkv, hd), jnp.float32)
    pt = jax.random.permutation(jax.random.PRNGKey(43),
                                jnp.arange(1, num_pages)).reshape(b, nb)
    lengths = jnp.asarray([1, page, page + 1,
                           min(3 * page + 2, nb * page)], jnp.int32)[:b]
    return q, kp, vp, pt, lengths


@pytest.mark.parametrize("page", [4, 8, 16])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (6, 0.0), (0, 25.0),
                                        (5, 30.0)])
def test_flash_decode_paged_parity(page, window, cap):
    """Block-indexed paged kernel (page table as scalar-prefetch operand)
    vs gather-the-pages-then-einsum, across page sizes, boundary-straddling
    ragged lengths, sliding window, softcap and both GQA head blocks."""
    from repro.kernels import ops
    from repro.kernels.flash_decode import flash_decode_paged
    q, kp, vp, pt, lengths = _paged_case(page, nb=4)
    kd, vd = ops.paged_gather(kp, vp, pt)
    want = ops.flash_decode_ref(q, kd, vd, lengths, window=window, cap=cap)
    for bh in (1, 2):
        out = flash_decode_paged(q, kp, vp, lengths, pt, bh=bh,
                                 window=window, cap=cap, interpret=True)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5,
                                   err_msg=f"page={page} bh={bh}")


def test_flash_decode_paged_single_row_matches_batch():
    """B=1 vs full batch: each row of the batched paged kernel equals its
    own single-row call (rows are independent grid slices)."""
    from repro.kernels.flash_decode import flash_decode_paged
    q, kp, vp, pt, lengths = _paged_case(page=8, nb=3, b=3)
    full = flash_decode_paged(q, kp, vp, lengths, pt, interpret=True)
    for r in range(q.shape[0]):
        solo = flash_decode_paged(q[r:r + 1], kp, vp, lengths[r:r + 1],
                                  pt[r:r + 1], interpret=True)
        np.testing.assert_allclose(full[r], solo[0], rtol=1e-6, atol=1e-6)


def test_ops_flash_decode_paged_routes_agree():
    """The ops wrapper's XLA fallback (gather + einsum oracle) and its
    Pallas route must produce the same output for the same pools."""
    from repro.kernels import ops
    q, kp, vp, pt, lengths = _paged_case(page=8, nb=4)
    saved = dict(ops._STATE)
    try:
        ops.use_pallas(False)
        fallback = ops.flash_decode_paged(q, kp, vp, lengths, pt,
                                          window=5, cap=30.0)
        ops.use_pallas(True, interpret=True)
        kernel = ops.flash_decode_paged(q, kp, vp, lengths, pt,
                                        window=5, cap=30.0)
    finally:
        ops._STATE.update(saved)
    np.testing.assert_allclose(kernel, fallback, rtol=2e-5, atol=2e-5)
