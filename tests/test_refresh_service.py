"""Distributed curvature service (repro.distributed): plan, sharded
refresh, async overlap — 1-device tier-1 coverage.

The numerics contract is pinned here on one device (sharded refresh ==
serial refresh, bitwise, for every inv_mode) and re-pinned on a forced
8-device CPU mesh by ``tests/test_distributed_numerics.py``; the plan's
balance guarantee gets a hypothesis property test in
``tests/test_property.py``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optimizers
from repro.configs.base import KFACConfig
from repro.data.pipeline import SyntheticAutoencoderData
from repro.distributed import (CHAIN, OverlapController, bin_pack,
                               block_cost, build_plan,
                               build_sharded_refresh)
from repro.models.mlp import MLP


def _problem(dims=(32, 16, 8, 16, 32), n=256):
    mlp = MLP(list(dims), nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(dims[0], 6, n, seed=7)
    return mlp, params, data


def _run(cfg, steps=10, poll=True):
    mlp, params, data = _problem()
    opt = optimizers.kfac(mlp, cfg, family="bernoulli")
    state = opt.init(params, data.batch(0))
    history = []
    for step in range(steps):
        batch = data.batch(step)
        rng = jax.random.fold_in(jax.random.PRNGKey(0), step)
        params, state, metrics = opt.update(None, state, params, batch, rng)
        if poll and opt.poll is not None:
            state = opt.poll(state)
        history.append({k: float(v) for k, v in metrics.items()
                        if jnp.ndim(v) == 0})
    return params, state, history


def _assert_trees_equal(a, b, err=""):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(x, y, err_msg=err), a, b)


# ---------------------------------------------------------------------------
# sharded refresh == serial refresh, bitwise (1 device; the 8-device
# re-pin lives in test_distributed_numerics.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inv_mode", ["blkdiag", "eigen", "tridiag"])
def test_sharded_refresh_matches_serial_bitwise(inv_mode):
    """10 steps covering warmup refreshes, a T3 refresh and a T2 gamma
    sweep: params AND inverses must agree bit-for-bit across refresh
    executors — the sharded path computes each block with the identical
    per-block math and only psum-adds exact zeros."""
    cfg = KFACConfig(inv_mode=inv_mode, inverse_method="eigh",
                     lambda_init=1.0, t1=5, t2=4, t3=5, eta=1e-5)
    p_serial, s_serial, _ = _run(cfg)
    p_shard, s_shard, _ = _run(cfg.replace(refresh_mode="sharded"))
    _assert_trees_equal(p_serial, p_shard, err=f"params ({inv_mode})")
    _assert_trees_equal(s_serial.inv, s_shard.inv, err=f"inv ({inv_mode})")
    np.testing.assert_array_equal(s_serial.lam, s_shard.lam)


def test_sharded_refresh_matches_serial_ns_hot_start():
    """The Newton–Schulz hot start consumes the previous inverses; the
    sharded refresh must thread them through identically."""
    cfg = KFACConfig(inv_mode="blkdiag", inverse_method="ns",
                     lambda_init=1.0, t1=5, t2=0, t3=3, eta=1e-5)
    p_serial, s_serial, _ = _run(cfg, steps=8)
    p_shard, s_shard, _ = _run(cfg.replace(refresh_mode="sharded"), steps=8)
    _assert_trees_equal(p_serial, p_shard)
    _assert_trees_equal(s_serial.inv, s_shard.inv)


def test_refresh_fn_output_matches_engine_stage():
    """build_sharded_refresh is the engine's refresh_inverses, relocated:
    same inv pytree from the same state."""
    mlp, params, data = _problem()
    cfg = KFACConfig(inv_mode="blkdiag", inverse_method="eigh",
                     lambda_init=1.0)
    opt = optimizers.kfac(mlp, cfg, family="bernoulli")
    state = opt.init(params, data.batch(0))
    state, grads, _ = opt.engine.stats_grads(state, params, data.batch(0),
                                             jax.random.PRNGKey(1))
    want = opt.engine.refresh_inverses(state).inv
    fn = build_sharded_refresh(opt.engine)
    got = fn(state.factors, state.gamma, state.inv)
    _assert_trees_equal(want, got)


# ---------------------------------------------------------------------------
# overlap mode
# ---------------------------------------------------------------------------

def test_overlap_mode_trains_with_bounded_staleness():
    cfg = KFACConfig(inv_mode="blkdiag", inverse_method="eigh",
                     lambda_init=1.0, t1=5, t2=8, t3=3, eta=1e-5,
                     refresh_mode="overlap")
    params, state, history = _run(cfg, steps=12)
    losses = [h["loss"] for h in history]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    # the staleness counter is bounded by T3 (forced swap at the ceiling)
    stale = [h.get("staleness", 0.0) for h in history]
    assert max(stale) <= cfg.t3, stale
    assert int(state.staleness) <= cfg.t3
    # the double buffer exists and, once committed, mirrors the live invs
    assert state.inv_pending is not None
    _assert_trees_equal(state.inv, state.inv_pending)


def test_overlap_state_slots_absent_in_sync_modes():
    """Serial/staggered/sharded states carry no pending buffer (None) —
    overlap's double buffer is paid for only when asked for."""
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    for mode in ("serial", "staggered", "sharded"):
        opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0,
                                              refresh_mode=mode),
                              family="bernoulli")
        state = opt.init(params, data.batch(0))
        assert state.inv_pending is None, mode
        assert int(state.staleness) == 0, mode
    opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0,
                                          refresh_mode="overlap"),
                          family="bernoulli")
    assert opt.init(params, data.batch(0)).inv_pending is not None


def test_overlap_controller_forced_commit_at_bound():
    """A pending buffer that never reports ready is force-committed when
    the staleness counter hits the bound (and at the next due step)."""

    class _Stuck:
        """Array stand-in that is never 'ready' until blocked on."""

        def __init__(self, v):
            self.v = v

        def is_ready(self):
            return False

    @dataclasses.dataclass(frozen=True)
    class MiniState:
        factors: object
        gamma: object
        inv: object
        inv_pending: object
        staleness: object

        def replace(self, **kw):
            return dataclasses.replace(self, **kw)

    calls = []

    def fake_refresh(factors, gamma, prev):
        calls.append(True)
        return {"w": _Stuck(len(calls))}

    ctl = OverlapController(fake_refresh, bound=3)
    state = MiniState(factors={}, gamma=1.0, inv={"w": 0},
                      inv_pending={"w": 0}, staleness=jnp.int32(0))
    state = ctl.on_refresh_stage(state, step=3, due=True)     # dispatch
    assert ctl.pending is not None and len(calls) == 1
    state = ctl.on_refresh_stage(state, step=4, due=False)
    state = ctl.on_refresh_stage(state, step=5, due=False)
    assert int(state.staleness) == 2 and ctl.pending is not None
    state = ctl.on_refresh_stage(state, step=6, due=True)     # forced
    assert int(state.staleness) == 0
    assert state.inv["w"].v == 1                              # committed
    assert len(calls) == 2                                    # re-dispatched
    # poll never blocks: the new stuck buffer stays pending
    state = ctl.poll(state)
    assert ctl.pending is not None and state.inv["w"].v == 1


def test_unknown_refresh_mode_rejected():
    mlp, _, _ = _problem(dims=(16, 8, 16), n=64)
    with pytest.raises(ValueError, match="refresh_mode"):
        optimizers.kfac(mlp, KFACConfig(refresh_mode="warp"),
                        family="bernoulli")


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

def test_bin_pack_covers_and_balances():
    costs = {f"b{i}": float(c) for i, c in
             enumerate([100, 90, 80, 10, 10, 10, 5, 5])}
    owners = bin_pack(costs, 3)
    assert set(owners) == set(costs)
    assert set(owners.values()) <= {0, 1, 2}
    loads = [0.0] * 3
    for n, b in owners.items():
        loads[b] += costs[n]
    # LPT guarantee: no bin exceeds the lightest by more than one item
    assert max(loads) - max(costs.values()) <= min(loads) + 1e-9
    # deterministic
    assert owners == bin_pack(dict(reversed(list(costs.items()))), 3)


def test_block_cost_model_shapes():
    @dataclasses.dataclass
    class Meta:
        a_dim: int = 64
        g_dim: int = 32
        a_kind: str = "full"
        g_kind: str = "full"
        a_blocks: int = 1
        g_blocks: int = 1
        n_stack: int = 0
        n_expert: int = 0

    assert block_cost(Meta()) == 64 ** 3 + 32 ** 3
    assert block_cost(Meta(a_kind="diag")) == 64 + 32 ** 3
    assert block_cost(Meta(g_kind="block", g_blocks=4)) == \
        64 ** 3 + 4 * 8 ** 3
    assert block_cost(Meta(n_stack=3)) == 3 * (64 ** 3 + 32 ** 3)


def test_build_plan_and_stagger_groups_partition_blocks():
    mlp, _, _ = _problem()
    cfg = KFACConfig(lambda_init=1.0, t3=3)
    eng = optimizers.kfac(mlp, cfg, family="bernoulli").engine
    plan = build_plan(eng.blocks, 4)
    assert sorted(plan.owners) == sorted(eng.blocks)
    assert plan.parallel_cost() < plan.serial_cost()
    # tridiag chain rides along as one more ownable unit
    plan_c = build_plan(eng.blocks, 4, chain=True)
    assert CHAIN in plan_c.owners
    # the engine's staggered groups are the same planner, T3 bins
    groups = eng.stagger_groups()
    assert len(groups) == cfg.t3
    assert sorted(n for g in groups for n in g) == sorted(eng.metas)
