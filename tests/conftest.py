import os

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets it itself, in-process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
