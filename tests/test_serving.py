"""Serving engine: continuous batching, paged KV cache, per-slot positions.

Regression pins for the three fixed-slot-engine bugs (cross-slot prefill
corruption, the global-position clobber / zero-KV attention leak, the
one-token-early termination), the paged-allocator invariants, and the
tentpole acceptance: batched output token-identical to the slot-serial
reference under greedy decoding across interleaved refills.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.lm import LM
from repro.serving.allocator import NULL_PAGE, PageAllocator
from repro.serving.server import Engine, Request, serial_engine


@pytest.fixture(scope="module")
def smollm():
    cfg = get_reduced_config("smollm-135m")
    lm = LM(cfg)
    return lm, lm.init_params(jax.random.PRNGKey(0)), cfg


@pytest.fixture(scope="module")
def gemma2():
    cfg = get_reduced_config("gemma2-2b")
    lm = LM(cfg)
    return lm, lm.init_params(jax.random.PRNGKey(0)), cfg


def _reqs(cfg, spec):
    """spec: list of (uid, prompt_len, max_new)."""
    return [Request(uid=u, prompt=[(7 * u + j) % cfg.vocab_size
                                   for j in range(tp)], max_new=mn)
            for u, tp, mn in spec]


# ---------------------------------------------------------------------------
# satellite 1: prefill of a refilled slot must not disturb active slots
# ---------------------------------------------------------------------------

def test_refill_does_not_disturb_active_slots(smollm):
    """Interleave a refill (request C prefilling into A's freed slot)
    between two of B's decode steps: B's tokens must be unchanged vs an
    undisturbed run.  The old engine's unmasked full-batch prefill rewrote
    every active slot's KV at the prefill positions."""
    lm, params, cfg = smollm
    spec_ab = [(0, 3, 2), (1, 4, 10)]       # A finishes early, B keeps going
    spec_c = [(2, 5, 4)]

    eng = Engine(lm, params, batch_slots=2, max_len=32)
    disturbed = _reqs(cfg, spec_ab) + _reqs(cfg, spec_c)
    rep = eng.run(disturbed)
    assert all(r.done for r in disturbed)
    # C really was admitted mid-run, between B's decode steps
    assert rep.steps > 2

    eng2 = Engine(lm, params, batch_slots=2, max_len=32)
    undisturbed = _reqs(cfg, spec_ab)
    eng2.run(undisturbed)
    assert disturbed[1].out == undisturbed[1].out, (
        "refill prefill corrupted a surviving slot's KV cache")
    assert disturbed[0].out == undisturbed[0].out


# ---------------------------------------------------------------------------
# satellite 2: per-slot positions — no global clobber, no zero-KV leak
# ---------------------------------------------------------------------------

def test_mixed_prompt_lengths_match_single_request(smollm):
    """Slots with very different prompt lengths decode concurrently; each
    must match its single-request (slot-serial) output exactly.  The old
    engine teleported lagging slots to the batch max position, attending
    zeroed-but-present KV entries."""
    lm, params, cfg = smollm
    spec = [(0, 2, 6), (1, 9, 6), (2, 5, 6)]

    eng = Engine(lm, params, batch_slots=3, max_len=32)
    batched = _reqs(cfg, spec)
    eng.run(batched)
    assert all(r.done for r in batched)

    for one in spec:
        ser = serial_engine(lm, params, max_len=32)
        solo = _reqs(cfg, [one])
        ser.run(solo)
        b = next(r for r in batched if r.uid == one[0])
        assert b.out == solo[0].out, (
            f"uid {one[0]}: batched {b.out} != single-request {solo[0].out}")


# ---------------------------------------------------------------------------
# satellite 3: termination — full cache usable, max_steps reported
# ---------------------------------------------------------------------------

def test_termination_uses_full_cache(smollm):
    """A cache of max_len yields exactly max_len usable positions: prompt
    Tp emits max_len - Tp + 1 tokens (first from prefill logits, last
    sampled-but-never-written).  The old `pos + 1 >= max_len - 1` ended one
    token early."""
    lm, params, _ = smollm
    eng = Engine(lm, params, batch_slots=1, max_len=16)
    req = Request(uid=0, prompt=[1, 2, 3, 4], max_new=100)
    eng.run([req])
    assert req.done
    assert len(req.out) == 16 - 4 + 1


def test_max_steps_reports_pending(smollm):
    lm, params, cfg = smollm
    eng = Engine(lm, params, batch_slots=1, max_len=16)
    reqs = _reqs(cfg, [(i, 3, 8) for i in range(3)])
    rep = eng.run(reqs, max_steps=2)
    assert rep.truncated
    assert [r.uid for r in rep.unfinished] == [0]
    assert [r.uid for r in rep.unserved] == [1, 2]
    assert not rep.unfinished[0].done and rep.unfinished[0].out  # partial


def test_submit_rejects_invalid_requests(smollm):
    lm, params, _ = smollm
    eng = Engine(lm, params, batch_slots=1, max_len=8)
    bad_empty = Request(uid=0, prompt=[])
    bad_long = Request(uid=1, prompt=list(range(9)), max_new=2)
    ok = Request(uid=2, prompt=[1, 2], max_new=2)
    rep = eng.run([bad_empty, bad_long, ok])
    assert bad_empty.error and bad_long.error
    assert [r.uid for r in rep.failed] == [0, 1]
    assert ok.done and len(ok.out) == 2


# ---------------------------------------------------------------------------
# satellite 4: flash_decode must not silently run the interpreter
# ---------------------------------------------------------------------------

def test_flash_decode_interpret_not_hardcoded():
    from repro.kernels.flash_decode import flash_decode
    default = inspect.signature(flash_decode).parameters["interpret"].default
    assert default is None, (
        "flash_decode's interpret default must resolve from the backend, "
        "not hardcode interpreter mode")


def test_ops_flash_decode_masks_per_row():
    """The einsum fallback masks each row at its own length (and window)."""
    from repro.kernels import ops
    b, hq, hkv, s, hd = 3, 4, 2, 32, 8
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, hd))
    k = jax.random.normal(kk, (b, hkv, s, hd))
    v = jax.random.normal(kv, (b, hkv, s, hd))
    lengths = jnp.asarray([1, 17, 32], jnp.int32)
    out = ops.flash_decode(q, k, v, lengths)
    for row, ln in enumerate(map(int, lengths)):
        ref = ops.flash_decode(q[row:row + 1], k[row:row + 1],
                               v[row:row + 1], ln)
        np.testing.assert_allclose(out[row], ref[0], rtol=1e-6, atol=1e-6)
    # window + cap per-row vs a dense reference
    outw = ops.flash_decode(q, k, v, lengths, window=8, cap=20.0)
    g = hq // hkv
    qg = np.asarray(q).reshape(b, hkv, g, hd)
    sc = np.einsum("bhgd,bhsd->bhgs", qg, np.asarray(k)) / np.sqrt(hd)
    sc = 20.0 * np.tanh(sc / 20.0)
    pos = np.arange(s)
    ln = np.asarray(lengths)[:, None]
    valid = (pos[None] < ln) & (pos[None] >= ln - 8)
    sc = np.where(valid[:, None, None, :], sc, -1e30)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = np.einsum("bhgs,bhsd->bhgd", p, np.asarray(v)).reshape(b, hq, hd)
    np.testing.assert_allclose(outw, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# satellite 5: paged allocator properties
# ---------------------------------------------------------------------------

def test_allocator_basics():
    a = PageAllocator(5)
    assert a.capacity == 4 and NULL_PAGE not in a.free_pages
    pages = a.alloc(4)
    assert sorted(pages) == [1, 2, 3, 4]
    assert a.alloc(1) is None and a.n_free == 0
    with pytest.raises(ValueError):
        a.free([NULL_PAGE])
    a.free(pages)
    with pytest.raises(ValueError):
        a.free([pages[0]])          # double free
    assert a.n_free == 4


def _allocator_sequence_invariants(ops_list, num_pages):
    """Any alloc/free/evict/re-admit interleaving: no page is ever in two
    live allocations, no page leaks (free + held always partitions the
    capacity), the null page is never handed out, and evictions return
    pages to the *same* free list (re-admission after eviction reuses
    them) while the eviction counter tracks exactly the evicted pages."""
    a = PageAllocator(num_pages)
    live = []                                    # list of page-lists
    evicted_total = 0
    for kind, n in ops_list:
        if kind == 0 or not live:                # alloc (or forced when empty)
            got = a.alloc(n)
            if got is None:
                assert n > a.n_free, "alloc refused despite enough pages"
                continue
            assert len(got) == n and NULL_PAGE not in got
            live.append(got)
        elif kind == 1:                          # free (request finished)
            a.free(live.pop(n % len(live)))
        else:                                    # evict (request preempted)
            pages = live.pop(n % len(live))
            a.evict(pages)
            evicted_total += len(pages)
        held = [p for pages in live for p in pages]
        assert len(held) == len(set(held)), "page double-assigned"
        assert sorted(held + a.free_pages) == list(range(1, num_pages)), \
            "page leaked or duplicated"
        assert a.n_evicted == evicted_total
    for pages in live:
        a.free(pages)
    assert a.n_free == a.capacity
    with pytest.raises(ValueError):
        a.evict([NULL_PAGE])                     # reserved page never evicted


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 6)),
                    max_size=60),
           st.integers(2, 12))
    def test_allocator_never_double_assigns_or_leaks(ops_list, num_pages):
        _allocator_sequence_invariants(ops_list, num_pages)
else:                                 # pragma: no cover
    def test_allocator_never_double_assigns_or_leaks():
        # hypothesis unavailable: fixed pseudo-random sequences instead
        rng = np.random.RandomState(0)
        for trial in range(20):
            ops_list = [(int(rng.randint(3)), int(rng.randint(7)))
                        for _ in range(60)]
            _allocator_sequence_invariants(ops_list,
                                           int(rng.randint(2, 13)))


def test_page_reuse_fully_overwritten_before_attended(smollm):
    """Free pages are poisoned with a huge finite value between requests;
    if a reused page were attended before being fully overwritten, the
    poison would blow up the logits and change the tokens."""
    lm, params, cfg = smollm
    eng = Engine(lm, params, batch_slots=1, max_len=16, page_size=4)
    first = _reqs(cfg, [(0, 6, 5)])
    eng.run(first)
    assert first[0].done
    free = jnp.asarray(eng.alloc.free_pages + [NULL_PAGE], jnp.int32)
    eng.pools = {name: {kv: p[kv].at[:, free].set(7777.0)
                        for kv in ("k", "v")}
                 for name, p in eng.pools.items()}
    second = _reqs(cfg, [(1, 5, 6)])
    eng.run(second)

    fresh = Engine(lm, params, batch_slots=1, max_len=16, page_size=4)
    clean = _reqs(cfg, [(1, 5, 6)])
    fresh.run(clean)
    assert second[0].out == clean[0].out, (
        "a reused page was attended before being fully overwritten")


# ---------------------------------------------------------------------------
# tentpole acceptance: batched == slot-serial, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm", "gemma2"])
def test_batched_matches_serial_token_for_token(arch, smollm, gemma2):
    """Greedy decoding with interleaved refills (more requests than slots,
    ragged prompt lengths and max_new): the continuous-batching engine must
    be token-identical to the slot-serial reference."""
    lm, params, cfg = smollm if arch == "smollm" else gemma2
    spec = [(0, 3, 4), (1, 6, 9), (2, 4, 2), (3, 8, 5), (4, 3, 7),
            (5, 6, 3), (6, 4, 6)]

    eng = Engine(lm, params, batch_slots=3, max_len=32)
    batched = _reqs(cfg, spec)
    rep = eng.run(batched)
    assert all(r.done for r in batched)
    assert rep.steps < sum(mn for _, _, mn in spec)  # actually batched

    ser = serial_engine(lm, params, max_len=32)
    serial = _reqs(cfg, spec)
    ser.run(serial)
    assert all(r.done for r in serial)

    for b, s in zip(batched, serial):
        assert b.out == s.out, (arch, b.uid, b.out, s.out)


def test_cache_pools_zero_at_construction(smollm):
    lm, params, _ = smollm
    eng = Engine(lm, params, batch_slots=2, max_len=16)
    for leaf in jax.tree.leaves(eng.cache):
        assert float(jnp.abs(leaf).max()) == 0.0


def test_unsupported_arch_rejected():
    cfg = get_reduced_config("jamba-1.5-large-398b")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        Engine(lm, params, batch_slots=1, max_len=16)


# ---------------------------------------------------------------------------
# paged decode route: block-indexed default vs the dense-gather oracle
# ---------------------------------------------------------------------------

def test_paged_route_is_default_and_matches_gather_oracle(smollm):
    """The block-indexed paged route (default) must be token-identical to
    the dense-gather oracle route on the same request stream."""
    lm, params, cfg = smollm
    spec = [(0, 3, 4), (1, 6, 9), (2, 4, 2), (3, 8, 5), (4, 3, 7)]

    eng = Engine(lm, params, batch_slots=3, max_len=32)
    assert eng.decode_route == "paged"
    paged = _reqs(cfg, spec)
    eng.run(paged)
    assert all(r.done for r in paged)

    ora = Engine(lm, params, batch_slots=3, max_len=32,
                 decode_route="gather")
    oracle = _reqs(cfg, spec)
    ora.run(oracle)
    for a, b in zip(paged, oracle):
        assert a.out == b.out, ("paged vs gather", a.uid, a.out, b.out)


# ---------------------------------------------------------------------------
# eviction / preemption: admission without worst-case reservation
# ---------------------------------------------------------------------------

def test_admission_reserves_prompt_pages_only(smollm):
    """Two requests whose combined *worst-case* footprint exceeds the pool
    must still decode concurrently — admission reserves only prompt pages
    (the old engine serialized them behind a full max_new reservation)."""
    lm, params, cfg = smollm
    # 5 allocatable pages; each request's worst case is blocks_for(15) = 4
    eng = Engine(lm, params, batch_slots=2, max_len=16, page_size=4,
                 num_pages=6)
    reqs = _reqs(cfg, [(0, 4, 12), (1, 4, 12)])
    for r in reqs:
        assert eng.submit(r)
    eng.step_once()
    assert eng.sched.n_active == 2, (
        "worst-case reservation blocked concurrent admission")
    eng.run([], max_steps=500)          # drain
    assert all(r.done for r in reqs)


def test_batched_matches_serial_under_eviction_pressure(smollm):
    """Tiny page pool forces mid-decode preemption: victims are evicted,
    re-queued at the front and recomputed from scratch — every request must
    still finish with exactly the tokens of an unpressured run."""
    lm, params, cfg = smollm
    spec = [(0, 3, 4), (1, 6, 9), (2, 4, 2), (3, 8, 5), (4, 3, 7)]

    tight = Engine(lm, params, batch_slots=3, max_len=32, page_size=4,
                   num_pages=7)
    pressured = _reqs(cfg, spec)
    rep = tight.run(pressured, max_steps=500)
    assert all(r.done for r in pressured), [
        (r.uid, r.state, r.error) for r in pressured]
    assert rep.preemptions > 0, "pool too large to exercise preemption"
    assert tight.alloc.n_evicted > 0
    assert any(r.preemptions > 0 for r in pressured)

    roomy = Engine(lm, params, batch_slots=3, max_len=32, page_size=4)
    clean = _reqs(cfg, spec)
    roomy.run(clean)
    for a, b in zip(pressured, clean):
        assert a.out == b.out, (
            "preempted re-run diverged", a.uid, a.preemptions, a.out, b.out)


# ---------------------------------------------------------------------------
# sampling: greedy bitwise-stable, seeded streams batch-independent
# ---------------------------------------------------------------------------

def test_sampling_filters_and_greedy():
    from repro.serving.sampling import filter_logits, sample_token
    row = np.asarray([1.0, 3.0, 3.0, 2.0, -1.0])
    # greedy is exactly np.argmax (first max wins ties) — the PR-7 path
    assert sample_token(row) == int(np.argmax(row)) == 1
    # top-k keeps the k highest, ties broken toward the lower token id
    f = filter_logits(row, top_k=2)
    assert np.isfinite(f[[1, 2]]).all() and not np.isfinite(f[[0, 3, 4]]).any()
    # top-p keeps the smallest descending-probability prefix reaching p;
    # at least one token always survives
    f = filter_logits(np.asarray([10.0, 0.0, 0.0]), top_p=0.5)
    assert np.isfinite(f[0]) and not np.isfinite(f[1:]).any()
    f = filter_logits(np.asarray([0.0, 0.0]), top_p=1e-9)
    assert np.isfinite(f).sum() == 1
    # seeded draws are a pure function of (seed, index)
    row2 = np.random.RandomState(0).randn(32)
    a = [sample_token(row2, temperature=0.8, seed=5, index=i)
         for i in range(8)]
    b = [sample_token(row2, temperature=0.8, seed=5, index=i)
         for i in range(8)]
    assert a == b
    assert a != [sample_token(row2, temperature=0.8, seed=6, index=i)
                 for i in range(8)]


def test_seeded_streams_independent_of_batch_composition(smollm):
    """A seeded request's token stream must not depend on what else is in
    the batch: batched seeded run == solo serial run, per request."""
    lm, params, cfg = smollm
    spec = [(0, 4, 6), (1, 6, 6), (2, 4, 5)]
    eng = Engine(lm, params, batch_slots=3, max_len=32)
    batched = [Request(uid=u, prompt=[(7 * u + j) % cfg.vocab_size
                                      for j in range(tp)], max_new=mn,
                       temperature=0.9, top_k=20, top_p=0.95, seed=100 + u)
               for u, tp, mn in spec]
    eng.run(batched)
    assert all(r.done for r in batched)
    for u, tp, mn in spec:
        ser = serial_engine(lm, params, max_len=32)
        solo = [Request(uid=u, prompt=[(7 * u + j) % cfg.vocab_size
                                       for j in range(tp)], max_new=mn,
                        temperature=0.9, top_k=20, top_p=0.95, seed=100 + u)]
        ser.run(solo)
        b = next(r for r in batched if r.uid == u)
        assert b.out == solo[0].out, (u, b.out, solo[0].out)


def test_seeded_streams_independent_of_admission_order(smollm):
    """Submitting the same seeded requests in a different order must not
    change any request's stream (per-request fold_in keys, no shared RNG)."""
    lm, params, cfg = smollm
    spec = [(0, 4, 5), (1, 6, 5), (2, 5, 5), (3, 4, 5)]

    def mk(u, tp, mn):
        return Request(uid=u, prompt=[(7 * u + j) % cfg.vocab_size
                                      for j in range(tp)], max_new=mn,
                       temperature=0.7, top_k=15, seed=50 + u)

    e1 = Engine(lm, params, batch_slots=2, max_len=32)
    fwd = [mk(*s) for s in spec]
    e1.run(fwd)
    e2 = Engine(lm, params, batch_slots=2, max_len=32)
    rev = [mk(*s) for s in reversed(spec)]
    e2.run(rev)
    by_uid = {r.uid: r for r in rev}
    for r in fwd:
        assert r.out == by_uid[r.uid].out, (r.uid, r.out, by_uid[r.uid].out)
