"""Golden-run regression tests: 50 deterministic seeded K-FAC steps on the
reduced deep-autoencoder config (the paper's S13/S14 benchmark family,
miniature) for every ``inv_mode``, asserted against a stored loss-trajectory
envelope.

Unit tests pin the pieces; this pins the *composition* — and it runs the
real ``Trainer.fit`` loop (warmup refreshes, T3 schedule, eigen rescale,
T2 gamma sweeps, T1 lambda rule, non-finite guard), not a re-implementation,
so a silently wrong schedule or preconditioner shows up here even when it
still descends.  The bands are generous (CPU BLAS reductions differ across
hosts) but far tighter than the gap to a broken optimizer: per-checkpoint
tolerance is a few percent while a misconfigured run drifts by tens of
percent within 20 steps (e.g. skipping the per-step EKFAC rescale moves the
late-trajectory loss well outside the band).

Regenerate after an *intentional* optimizer change with:
    PYTHONPATH=src python tests/test_golden.py
"""
import jax
import numpy as np
import pytest

from repro import optimizers
from repro.configs.autoencoder import reduced
from repro.configs.base import KFACConfig, TrainConfig
from repro.configs.conv_classifier import reduced as conv_reduced
from repro.data.pipeline import SyntheticAutoencoderData, SyntheticImageData
from repro.models.convnet import ConvNet
from repro.models.mlp import MLP, autoencoder_dims
from repro.training.trainer import Trainer

STEPS = 50
CHECKPOINTS = (0, 9, 19, 29, 39, 49)

# mode -> loss at each checkpoint step, from the run this file documents.
# Bands: rel=7% per checkpoint (platform spread on CPU f32 is <0.5%; an
# optimizer regression is an order of magnitude outside this).
GOLDEN = {
    "blkdiag": (93.1689, 42.0944, 36.7356, 32.6663, 29.4025, 26.9579),
    "eigen":   (93.1689, 42.1872, 36.6564, 32.5680, 29.3228, 26.9552),
    "tridiag": (93.1689, 41.9764, 37.0449, 32.9255, 29.7913, 27.4931),
}
REL_BAND = 0.07


def golden_run(inv_mode: str, steps: int = STEPS,
               refresh_mode: str = "serial", return_history: bool = False,
               fused_stats: bool = False):
    """The pinned setup: reduced autoencoder (64-32-16-8 mirrored), sparse
    paper init, full-batch synthetic data, eigh inverses, T3=5 refresh,
    driven end-to-end by the real Trainer."""
    dims = autoencoder_dims(reduced())
    mlp = MLP(dims, nonlin=reduced().nonlin, loss=reduced().loss)
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=True)
    data = SyntheticAutoencoderData(dims[0], 8, 256, seed=7)
    cfg = KFACConfig(inv_mode=inv_mode, inverse_method="eigh",
                     lambda_init=3.0, t3=5, eta=1e-5,
                     refresh_mode=refresh_mode, fused_stats=fused_stats,
                     # golden runs must be wall-clock independent: overlap
                     # commits exactly at due steps, not on is_ready races
                     overlap_deterministic=True)
    opt = optimizers.kfac(mlp, cfg, family="bernoulli")
    tr = Trainer(mlp, opt, TrainConfig(steps=steps, seed=0, log_every=10_000),
                 None, None)
    out = tr.fit(params, data, steps=steps, log=lambda *_: None)
    if return_history:
        return out["history"]
    return [h["loss"] for h in out["history"]]


@pytest.mark.slow
@pytest.mark.parametrize("inv_mode", sorted(GOLDEN))
def test_golden_trajectory(inv_mode):
    losses = golden_run(inv_mode)
    assert len(losses) == STEPS
    assert np.isfinite(losses).all(), losses
    want = GOLDEN[inv_mode]
    got = [losses[i] for i in CHECKPOINTS]
    for step, w, g in zip(CHECKPOINTS, want, got):
        assert abs(g - w) <= REL_BAND * w, (
            f"{inv_mode}: step {step} loss {g:.4f} outside "
            f"[{w * (1 - REL_BAND):.4f}, {w * (1 + REL_BAND):.4f}] "
            f"(golden {w:.4f}) — regenerate GOLDEN only for an "
            f"intentional optimizer change")
    # trajectory shape, not just endpoints: sustained descent
    assert losses[-1] < 0.35 * losses[0], (losses[0], losses[-1])
    assert all(b < a * 1.05 for a, b in zip(got, got[1:])), got


@pytest.mark.slow
@pytest.mark.parametrize("inv_mode", ["blkdiag", "eigen"])
def test_fused_stats_golden_trajectory(inv_mode):
    """fused_stats=True folds the factor accumulation into the backward
    pass (core/fused custom-VJP gg-probes + contract-map hooks); the
    statistics are the same numbers, so the run must sit inside the
    *existing* GOLDEN envelope — no separate pin."""
    losses = golden_run(inv_mode, fused_stats=True)
    want = GOLDEN[inv_mode]
    got = [losses[i] for i in CHECKPOINTS]
    for step, w, g in zip(CHECKPOINTS, want, got):
        assert abs(g - w) <= REL_BAND * w, (
            f"fused {inv_mode}: step {step} loss {g:.4f} deviates from the "
            f"two-pass golden {w:.4f} — fused statistics must not change "
            f"numerics")
    assert losses[-1] < 0.35 * losses[0], (losses[0], losses[-1])


# ---------------------------------------------------------------------------
# distributed refresh service (repro.distributed): the sharded refresh is
# bitwise-identical to serial, so it shares GOLDEN; the async overlap mode
# steps on pipelined (stale-by-design) inverses and gets its own envelope,
# plus the bounded-staleness contract (counter never exceeds T3).  The
# pinned run uses overlap_deterministic=True (swap exactly at due steps),
# so the trajectory is schedule-only — no is_ready wall-clock races.
# ---------------------------------------------------------------------------

GOLDEN_OVERLAP = (93.1689, 42.4726, 36.9508, 32.7847, 29.5379, 27.4448)


@pytest.mark.slow
def test_sharded_refresh_matches_serial_golden():
    """refresh_mode="sharded" must land on the *serial* golden trajectory:
    the block-parallel refresh is an executor change, not a numerics one."""
    losses = golden_run("blkdiag", refresh_mode="sharded")
    want = GOLDEN["blkdiag"]
    got = [losses[i] for i in CHECKPOINTS]
    for step, w, g in zip(CHECKPOINTS, want, got):
        assert abs(g - w) <= REL_BAND * w, (
            f"sharded: step {step} loss {g:.4f} deviates from the serial "
            f"golden {w:.4f} — the sharded refresh must not change numerics")


@pytest.mark.slow
def test_overlap_golden_trajectory():
    """50 Trainer.fit steps in refresh_mode="overlap": the double-buffered
    async refresh descends inside its own envelope and the staleness
    counter stays within the T3 bound throughout."""
    hist = golden_run("blkdiag", refresh_mode="overlap",
                      return_history=True)
    losses = [h["loss"] for h in hist]
    assert len(losses) == STEPS
    assert np.isfinite(losses).all(), losses
    got = [losses[i] for i in CHECKPOINTS]
    for step, w, g in zip(CHECKPOINTS, GOLDEN_OVERLAP, got):
        assert abs(g - w) <= REL_BAND * w, (
            f"overlap: step {step} loss {g:.4f} outside "
            f"[{w * (1 - REL_BAND):.4f}, {w * (1 + REL_BAND):.4f}] "
            f"(golden {w:.4f}) — regenerate GOLDEN_OVERLAP only for an "
            f"intentional optimizer/scheduling change")
    assert losses[-1] < 0.35 * losses[0], (losses[0], losses[-1])
    # bounded staleness: the controller force-swaps at the T3 ceiling
    stale = [h.get("staleness", 0.0) for h in hist]
    assert max(stale) <= 5, stale          # T3 = 5 in the pinned setup


# ---------------------------------------------------------------------------
# conv classifier (KFC, 1602.01407): the same 50-step envelope over the
# reduced ConvNet — pins the ConvKronecker composition (patch statistics,
# homogeneous bias, eigen rescale) through the real Trainer, per inv_mode.
# "tridiag" degrades to the block-diagonal inverse here (the chain
# approximation needs an MLP-style layer_order), so it doubles as a pin
# that the fallback stays exact.
# ---------------------------------------------------------------------------

# mode -> loss at each checkpoint step.  The descent is steep (the class
# templates are memorized by ~step 25) and late losses sit at the noise
# floor, so the band is wider than the autoencoder's and adds a small
# absolute term: rel 15% + abs 0.02 per checkpoint.
GOLDEN_CONV = {
    "blkdiag": (1.3467, 0.9343, 0.0888, 0.0137, 0.0048, 0.0019),
    "eigen":   (1.3467, 0.9342, 0.0887, 0.0137, 0.0048, 0.0019),
    "tridiag": (1.3467, 0.9343, 0.0888, 0.0137, 0.0048, 0.0019),
}
REL_BAND_CONV = 0.15
ABS_BAND_CONV = 0.02


def conv_golden_run(inv_mode: str, steps: int = STEPS):
    """Reduced conv classifier (two strided SAME convs + softmax head),
    full-batch synthetic class-template images, eigh inverses, T3=5,
    driven end-to-end by the real Trainer."""
    cfg = conv_reduced()
    net = ConvNet(cfg)
    params = net.init_params(jax.random.PRNGKey(0))
    data = SyntheticImageData(cfg.image_size, cfg.channels, cfg.n_classes,
                              128, seed=7)
    kcfg = KFACConfig(inv_mode=inv_mode, inverse_method="eigh",
                      lambda_init=3.0, t3=5, eta=1e-5)
    opt = optimizers.kfac(net, kcfg, family="categorical")
    tr = Trainer(net, opt, TrainConfig(steps=steps, seed=0, log_every=10_000),
                 None, None)
    out = tr.fit(params, data, steps=steps, log=lambda *_: None)
    return [h["loss"] for h in out["history"]]


@pytest.mark.slow
@pytest.mark.parametrize("inv_mode", sorted(GOLDEN_CONV))
def test_conv_golden_trajectory(inv_mode):
    losses = conv_golden_run(inv_mode)
    assert len(losses) == STEPS
    assert np.isfinite(losses).all(), losses
    want = GOLDEN_CONV[inv_mode]
    got = [losses[i] for i in CHECKPOINTS]
    for step, w, g in zip(CHECKPOINTS, want, got):
        band = REL_BAND_CONV * w + ABS_BAND_CONV
        assert abs(g - w) <= band, (
            f"conv/{inv_mode}: step {step} loss {g:.4f} outside "
            f"[{w - band:.4f}, {w + band:.4f}] (golden {w:.4f}) — "
            f"regenerate GOLDEN_CONV only for an intentional change")
    # sustained descent to well under the initial cross-entropy
    assert losses[-1] < 0.2 * losses[0], (losses[0], losses[-1])
    assert all(b < a + ABS_BAND_CONV for a, b in zip(got, got[1:])), got


if __name__ == "__main__":
    for mode in sorted(GOLDEN):
        ls = golden_run(mode)
        pts = ", ".join(f"{ls[i]:.4f}" for i in CHECKPOINTS)
        print(f'    "{mode}": ({pts}),')
    ls = golden_run("blkdiag", refresh_mode="overlap")
    pts = ", ".join(f"{ls[i]:.4f}" for i in CHECKPOINTS)
    print(f'    GOLDEN_OVERLAP = ({pts})')
    for mode in sorted(GOLDEN_CONV):
        ls = conv_golden_run(mode)
        pts = ", ".join(f"{ls[i]:.4f}" for i in CHECKPOINTS)
        print(f'    conv "{mode}": ({pts}),')
