"""The functional optimizer API (core/transform.py + optimizers/).

Pins three contracts:
  * the ``kfac(cfg)`` pipeline is BITWISE-identical to hand-driving the
    legacy ``KFAC`` stage methods with the paper's schedule, per inv_mode
    (the deprecation-shim parity — marked ``shim``);
  * the generic transforms (``sgd_momentum`` / ``adam``) match hand-rolled
    reference updates;
  * the typed states behave as ordinary pytrees (jit / eval_shape /
    legacy dict-style reads).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optimizers
from repro.configs.base import KFACConfig, TrainConfig
from repro.core import transform as TX
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP
from repro.training.trainer import Trainer
from repro.utils import tree as T


def _problem(dims=(32, 16, 8, 16, 32), n=256):
    mlp = MLP(list(dims), nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(dims[0], 6, n, seed=7)
    return mlp, params, data


def _assert_trees_equal(a, b, err=""):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(x, y, err_msg=err), a, b)


# ---------------------------------------------------------------------------
# legacy-shim parity: pipeline == manual five-call choreography, bitwise
# ---------------------------------------------------------------------------

def _legacy_loop(mlp, params, data, cfg, steps):
    """The pre-redesign Trainer.fit choreography, verbatim: stats →
    (multi+update3 | warmup/T3 refresh → eigen rescale → update) → lambda,
    each stage its own jit."""
    opt = KFAC(mlp, cfg, family="bernoulli")
    state = opt.init(params, data.batch(0))
    stats = jax.jit(opt.stats_grads)
    refresh = jax.jit(lambda s: opt.refresh_inverses(s, hot=True))
    rescale = jax.jit(opt.rescale_step)
    update = jax.jit(lambda s, p, g, b, r: opt.apply_update(s, p, g, b, r))
    multi = jax.jit(opt.refresh_multi)
    update3 = jax.jit(
        lambda s, p, g, b, r, gs, i3: opt.apply_update(
            s, p, g, b, r,
            cand_inv=[jax.tree.map(lambda x: x[c], i3) for c in range(3)],
            gammas=gs))
    lam_fn = jax.jit(opt.lambda_step)
    for step in range(steps):
        batch = data.batch(step)
        rng = jax.random.fold_in(jax.random.PRNGKey(0), step)
        state, grads, _ = stats(state, params, batch, rng)
        if cfg.t2 > 0 and step > 0 and step % cfg.t2 == 0:
            gs, i3 = multi(state)
            params, state, _ = update3(state, params, grads, batch, rng,
                                       gs, i3)
        else:
            if step < 3 or step % cfg.t3 == 0:
                state = refresh(state)
            if opt.eigen:
                state = rescale(state, grads)
            params, state, _ = update(state, params, grads, batch, rng)
        if cfg.t1 > 0 and (step + 1) % cfg.t1 == 0:
            state, _ = lam_fn(state, params, batch, rng)
    return params, state


def _pipeline_loop(mlp, params, data, cfg, steps):
    opt = optimizers.kfac(mlp, cfg, family="bernoulli")
    state = opt.init(params, data.batch(0))
    for step in range(steps):
        batch = data.batch(step)
        rng = jax.random.fold_in(jax.random.PRNGKey(0), step)
        params, state, _ = opt.update(None, state, params, batch, rng)
    return params, state


@pytest.mark.shim
@pytest.mark.parametrize("inv_mode", ["blkdiag", "tridiag", "eigen"])
def test_pipeline_matches_legacy_bitwise(inv_mode):
    """10 autoencoder steps covering warmup, T3 refresh, a T2 gamma sweep
    and two T1 lambda steps: params must agree bit-for-bit."""
    mlp, params, data = _problem()
    cfg = KFACConfig(inv_mode=inv_mode, inverse_method="eigh",
                     lambda_init=1.0, t1=5, t2=4, t3=5, eta=1e-5)
    p_legacy, s_legacy = _legacy_loop(mlp, params, data, cfg, steps=10)
    p_pipe, s_pipe = _pipeline_loop(mlp, params, data, cfg, steps=10)
    _assert_trees_equal(p_legacy, p_pipe, err=f"params ({inv_mode})")
    np.testing.assert_array_equal(s_legacy.lam, s_pipe.lam)
    np.testing.assert_array_equal(s_legacy.gamma, s_pipe.gamma)
    np.testing.assert_array_equal(s_legacy.step, s_pipe.step)
    assert not np.array_equal(jax.tree.leaves(params)[0],
                              jax.tree.leaves(p_pipe)[0])  # it DID train


@pytest.mark.shim
def test_trainer_wraps_legacy_engine():
    """Trainer(model, KFAC(...)) — the deprecation shim — takes the exact
    same trajectory as Trainer(model, optimizers.kfac(...))."""
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    cfg = KFACConfig(lambda_init=1.0, t3=2, t1=2, t2=6)
    tc = TrainConfig(steps=6, seed=0, log_every=10_000)
    out1 = Trainer(mlp, KFAC(mlp, cfg, family="bernoulli"), tc).fit(
        params, data, steps=6, log=lambda *_: None)
    out2 = Trainer(mlp, optimizers.kfac(mlp, cfg, family="bernoulli"),
                   tc).fit(params, data, steps=6, log=lambda *_: None)
    _assert_trees_equal(out1["params"], out2["params"])
    assert [h["loss"] for h in out1["history"]] == \
        [h["loss"] for h in out2["history"]]


def test_kfac_requires_none_grads():
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0),
                          family="bernoulli")
    batch = data.batch(0)
    state = opt.init(params, batch)
    with pytest.raises(ValueError, match="own gradients"):
        opt.update(T.tree_zeros_like(params), state, params, batch,
                   jax.random.PRNGKey(0))


def test_kfac_reject_raises_damping_and_clears_momentum():
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    opt = optimizers.kfac(mlp, KFACConfig(lambda_init=2.0),
                          family="bernoulli")
    state = opt.init(params, data.batch(0))
    state = state.replace(delta0=jax.tree.map(
        lambda x: x + 1.0, state.delta0))
    rej = opt.reject(state)
    assert float(rej.lam) == pytest.approx(8.0)
    assert all(float(jnp.abs(leaf).max()) == 0.0
               for leaf in jax.tree.leaves(rej.delta0))


# ---------------------------------------------------------------------------
# typed state
# ---------------------------------------------------------------------------

def test_kfac_state_is_typed_pytree():
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0),
                          family="bernoulli")
    batch = data.batch(0)
    state = opt.init(params, batch)
    assert isinstance(state, TX.KFACState)
    # dict-style legacy reads still work
    np.testing.assert_array_equal(state["lam"], state.lam)
    # flattens / jits / eval_shapes like any pytree
    n_leaves = len(jax.tree.leaves(state))
    assert n_leaves > 4
    rt = jax.jit(lambda s: s)(state)
    assert isinstance(rt, TX.KFACState) and len(jax.tree.leaves(rt)) == n_leaves
    abs_state = jax.eval_shape(opt.init, params, batch)
    assert isinstance(abs_state, TX.KFACState)
    assert abs_state.lam.dtype == jnp.float32
    # replace is functional
    s2 = state.replace(lam=jnp.float32(9.0))
    assert float(s2.lam) == 9.0 and float(state.lam) == 1.0


# ---------------------------------------------------------------------------
# generic transforms vs hand-rolled references
# ---------------------------------------------------------------------------

def _fake_grads(key, params):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, x.shape, x.dtype)
                  for k, x in zip(keys, leaves)])


def test_sgd_momentum_transform_matches_reference_bitwise():
    """v <- m v - lr g; the chained scale(-lr) |> with_momentum recursion
    must reproduce it exactly (same op sequence, eager both sides)."""
    _, params, _ = _problem(dims=(16, 8, 16), n=64)
    lr, mom = 0.1, 0.9
    tx = optimizers.sgd_momentum_transform(lr=lr, momentum=mom)
    s = tx.init(params)
    vel = T.tree_zeros_like(params)
    for i in range(4):
        g = _fake_grads(jax.random.PRNGKey(i), params)
        u, s = tx.update(g, s, params)
        vel = jax.tree.map(lambda v, gg: mom * v + (-lr) * gg, vel, g)
        _assert_trees_equal(u, vel, err=f"step {i}")


def test_adam_transform_matches_reference():
    _, params, _ = _problem(dims=(16, 8, 16), n=64)
    lr, b1, b2, eps = 1e-2, 0.9, 0.999, 1e-8
    tx = optimizers.adam_transform(lr=lr, b1=b1, b2=b2, eps=eps)
    s = tx.init(params)
    mu = T.tree_zeros_like(params)
    nu = T.tree_zeros_like(params)
    for i in range(4):
        g = _fake_grads(jax.random.PRNGKey(i), params)
        u, s = tx.update(g, s, params)
        t = i + 1
        mu = jax.tree.map(lambda m, gg: b1 * m + (1 - b1) * gg, mu, g)
        nu = jax.tree.map(lambda v, gg: b2 * v + (1 - b2) * gg * gg, nu, g)
        ref = jax.tree.map(
            lambda m, v: -lr * ((m / (1 - b1 ** t))
                                / (jnp.sqrt(v / (1 - b2 ** t)) + eps)),
            mu, nu)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6,
                                                    atol=1e-7), u, ref)


def test_adam_weight_decay_is_decoupled():
    """AdamW ordering: the wd*p term must NOT be rescaled by 1/sqrt(nu)."""
    p = {"w": jnp.array([2.0, -4.0])}
    g = {"w": jnp.array([1.0, 1.0])}
    lr, wd = 0.1, 0.01
    tx = optimizers.adam_transform(lr=lr, weight_decay=wd)
    u, _ = tx.update(g, tx.init(p), p)
    tx0 = optimizers.adam_transform(lr=lr)
    u0, _ = tx0.update(g, tx0.init(p), p)
    np.testing.assert_allclose(u["w"], u0["w"] - lr * wd * p["w"],
                               rtol=1e-6, atol=1e-8)


def test_kfac_lambda_step_survives_nan_update():
    """A poisoned step at a T1 boundary: the lambda stage evaluates the
    loss at the params the trainer will keep (the old, finite ones — never
    the NaN update), and lambda stays finite (a NaN rho leaves it as-is,
    the trainer's reject() then raises it)."""
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0, t1=1, t3=1),
                          family="bernoulli")
    batch = data.batch(0)
    state = opt.init(params, batch)
    # one clean step so loss_prev/m_delta are real
    params, state, metrics = opt.update(None, state, params, batch,
                                        jax.random.PRNGKey(0))
    assert np.isfinite(float(metrics["rho"]))
    lam_before = float(state.lam)
    # poison the momentum tangent -> the next update is non-finite
    state = state.replace(delta0=jax.tree.map(
        lambda x: jnp.full_like(x, jnp.nan), state.delta0))
    new_params, state, metrics = opt.update(None, state, params, batch,
                                            jax.random.PRNGKey(1))
    assert not bool(T.tree_isfinite(new_params))
    # m_delta is NaN on a poisoned step, so rho is too — but lambda must
    # not be corrupted, and reject() still escalates it cleanly
    assert np.isfinite(float(state.lam))
    assert float(state.lam) == pytest.approx(lam_before)
    assert float(opt.reject(state).lam) == pytest.approx(4 * lam_before)


def test_sgd_momentum_optimizer_matches_hand_rolled_loop():
    """End-to-end through the Optimizer's own gradient pass."""
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    batch = data.batch(0)
    lr, mom = 0.1, 0.9
    opt = optimizers.sgd_momentum(mlp, lr=lr, momentum=mom)
    state = opt.init(params)
    p_opt = params

    def loss_fn(p, rng):
        (lt, _), _ = mlp.loss(p, None, batch, rng, mode="plain")
        return lt

    gfn = jax.jit(jax.grad(loss_fn))
    vel = T.tree_zeros_like(params)
    p_ref = params
    for i in range(5):
        rng = jax.random.PRNGKey(i)
        p_opt, state, metrics = opt.update(None, state, p_opt, batch, rng)
        g = gfn(p_ref, rng)
        vel = jax.tree.map(lambda v, gg: mom * v - lr * gg, vel, g)
        p_ref = jax.tree.map(lambda p, v: p + v, p_ref, vel)
        assert {"loss", "grad_norm", "delta_norm"} <= set(metrics)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7),
        p_opt, p_ref)
    assert int(state.step) == 5


def test_clip_and_weight_decay_transforms():
    u = {"w": jnp.array([3.0, 4.0]), "b": jnp.zeros(2)}
    p = {"w": jnp.array([10.0, 0.0]), "b": jnp.ones(2)}
    clip = TX.clip_by_global_norm(1.0)
    out, _ = clip.update(u, clip.init(p), p)
    np.testing.assert_allclose(float(jnp.sqrt(T.tree_sqnorm(out))), 1.0,
                               rtol=1e-6)
    # under the bound: passthrough
    out2, _ = TX.clip_by_global_norm(100.0).update(u, (), p)
    _assert_trees_equal(out2, u)
    wd = TX.add_decayed_weights(0.1)
    out3, _ = wd.update(u, wd.init(p), p)
    np.testing.assert_allclose(out3["w"], u["w"] + 0.1 * p["w"])
    np.testing.assert_allclose(out3["b"], u["b"] + 0.1 * p["b"])


def test_chain_threads_state_and_updates():
    p = {"w": jnp.arange(4.0)}
    tx = TX.chain(TX.scale(2.0), TX.scale(0.5), TX.with_momentum(0.0))
    s = tx.init(p)
    assert isinstance(s, tuple) and len(s) == 3
    u, s = tx.update({"w": jnp.ones(4)}, s, p)
    np.testing.assert_allclose(u["w"], jnp.ones(4))


def test_from_transform_requires_model_or_grads():
    opt = optimizers.sgd_momentum(None, lr=0.1)
    p = {"w": jnp.ones(3)}
    state = opt.init(p)
    with pytest.raises(ValueError, match="no model"):
        opt.update(None, state, p, None, None)
    # explicit-grads (pure optax-style) path works without a model
    newp, state, metrics = opt.update({"w": jnp.ones(3)}, state, p)
    np.testing.assert_allclose(newp["w"], 1.0 - 0.1)
    assert float(metrics["delta_norm"]) > 0


def test_with_kl_clip_matches_hand_rolled():
    """ν = min(1, sqrt(max_kl / (lr²·|Δᵀg|))) against an explicit
    reference, wrapping a plain lr scale (Δ = -lr·g ⇒ |Δᵀg| = lr·|g|²)."""
    g = {"w": jnp.array([3.0, -4.0]), "b": jnp.array([1.0, 2.0, -2.0])}
    p = T.tree_scale(g, 0.0)
    lr, max_kl = 0.1, 1e-3
    tx = TX.with_kl_clip(TX.scale(-lr), max_kl, lr=1.0)
    out, _ = tx.update(g, tx.init(p), p)

    delta = T.tree_scale(g, -lr)
    quad = abs(float(T.tree_dot(delta, g)))
    nu = min(1.0, float(np.sqrt(max_kl / quad)))
    assert nu < 1.0                       # the clip actually engaged
    jax.tree_util.tree_map(
        lambda o, d: np.testing.assert_allclose(o, nu * d, rtol=1e-6),
        out, delta)

    # generous budget: passthrough, bitwise
    tx2 = TX.with_kl_clip(TX.scale(-lr), 1e6, lr=1.0)
    out2, _ = tx2.update(g, tx2.init(p), p)
    _assert_trees_equal(out2, delta)

    # the explicit-lr form: inner emits the raw direction Δ = -g and the
    # caller applies lr·Δ, so the trust region is on lr²·|Δᵀg|
    tx3 = TX.with_kl_clip(TX.scale(-1.0), max_kl, lr=lr)
    out3, _ = tx3.update(g, tx3.init(p), p)
    nu3 = min(1.0, float(np.sqrt(
        max_kl / (lr * lr * abs(float(T.tree_dot(g, g)))))))
    jax.tree_util.tree_map(
        lambda o, gg: np.testing.assert_allclose(o, -nu3 * gg, rtol=1e-6),
        out3, g)


def test_kfac_kl_clip_engine_paths():
    """KFACConfig.kl_clip on the fused fixed-lr update: a generous budget
    is bitwise-identical to kl_clip=0 (off), a tight one shrinks every
    step and tracks the hand-computed ν."""
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    batch = data.batch(0)

    def run(kl_clip, steps=4):
        cfg = KFACConfig(inv_mode="blkdiag", use_rescale=False,
                         fixed_lr=0.05, lambda_init=1.0, t3=2,
                         kl_clip=kl_clip)
        opt = optimizers.kfac(mlp, cfg, family="bernoulli")
        state = opt.init(params, batch)
        p, norms = params, []
        for step in range(steps):
            p, state, metrics = opt.update(
                None, state, p, batch,
                jax.random.fold_in(jax.random.PRNGKey(3), step))
            norms.append(float(metrics["delta_norm"]))
        return p, norms

    p_off, n_off = run(0.0)
    p_huge, n_huge = run(1e9)
    _assert_trees_equal(p_off, p_huge, "huge kl_clip must be a no-op")
    np.testing.assert_array_equal(n_off, n_huge)

    p_tight, n_tight = run(1e-5)
    assert all(t < o for t, o in zip(n_tight, n_off))
    assert not np.allclose(jax.tree.leaves(p_tight)[0],
                           jax.tree.leaves(p_off)[0])


# ---------------------------------------------------------------------------
# baselines race through the SAME Trainer.fit loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [
    lambda m: optimizers.sgd_momentum(m, lr=0.1, momentum=0.9),
    lambda m: optimizers.adam(m, lr=1e-2),
    lambda m: optimizers.get("kfac", m, kfac_cfg=KFACConfig(
        lambda_init=1.0, t3=2), family="bernoulli"),
], ids=["sgd_momentum", "adam", "kfac"])
def test_optimizers_race_through_one_trainer(make_opt):
    mlp, params, data = _problem(dims=(16, 8, 16), n=64)
    tr = Trainer(mlp, make_opt(mlp),
                 TrainConfig(steps=8, seed=0, log_every=10_000))
    out = tr.fit(params, data, steps=8, log=lambda *_: None)
    losses = [h["loss"] for h in out["history"]]
    assert len(losses) == 8
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
