"""Observability (repro.obs): metrics exactness, schema round-trip, the
disabled-is-bitwise-identical contract, and the shared latency split.

The load-bearing pins:

* **Disabled parity** — training with ``ObsConfig(enabled=False)`` (the
  default) produces bitwise-identical params/history to an enabled run,
  and the *same jitted program counts* per stage: instrumentation lives
  entirely host-side (spans block on outputs the host would eventually
  sync anyway; counters are plain host ints), so the compiled graphs
  cannot differ.  Ditto for serving tokens and the decode step's jit
  cache.
* **Exact percentiles** — ``Histogram.percentile`` must bit-match
  ``numpy.percentile`` (linear interpolation) over the bounded
  most-recent-N reservoir window.
* **Schema** — every event written through the sink round-trips through
  ``read_jsonl``'s validator, and ``benchmarks/obs_check.py`` (the CI
  gate) accepts/rejects correctly.
* **Thread safety** — concurrent writers from daemon threads (the
  OverlapController / BundleWriter pattern) never drop an increment or
  interleave a JSONL line.
"""
import dataclasses
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optimizers
from repro.configs.base import KFACConfig, TrainConfig
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP
from repro.obs import (Obs, ObsConfig, Registry, RequestLatencyTracker,
                       console_summary, percentile, prometheus_text,
                       read_jsonl, validate_event)
from repro.obs.export import JsonlSink
from repro.training.trainer import Trainer

DIMS = (20, 12, 8, 12, 20)


def _problem(n=128):
    mlp = MLP(list(DIMS), nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(DIMS[0], 8, n, seed=3)
    return mlp, params, data


# ---------------------------------------------------------------------------
# metrics: exact percentiles, labels, snapshots
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy():
    rs = np.random.RandomState(0)
    xs = list(rs.lognormal(size=257))
    for q in (0, 25, 50, 90, 99, 100):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q)), abs=0.0, rel=1e-12)


def test_histogram_percentiles_windowed():
    """p50/p99 are exact over the bounded most-recent-N window, matching
    numpy's linear interpolation — including once the reservoir rolls."""
    reg = Registry(reservoir=64)
    h = reg.histogram("lat_s")
    rs = np.random.RandomState(1)
    xs = rs.exponential(size=200)
    for x in xs:
        h.observe(float(x))
    window = xs[-64:]                       # most recent N survive
    for q in (50, 99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(window, q)), rel=1e-12)
    snap = h.snapshot()
    assert snap["count"] == 200             # totals cover ALL observations
    assert snap["sum"] == pytest.approx(float(xs.sum()))
    assert snap["max"] == pytest.approx(float(xs.max()))
    assert snap["p50"] == pytest.approx(float(np.percentile(window, 50)))


def test_registry_labels_and_kind_clash():
    reg = Registry()
    c1 = reg.counter("hits", {"route": "a"})
    c2 = reg.counter("hits", {"route": "b"})
    assert c1 is not c2
    assert reg.counter("hits", {"route": "a"}) is c1   # get-or-create
    c1.inc(); c1.inc(3)
    assert c1.value == 4
    with pytest.raises(ValueError):
        c1.inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("hits", {"route": "a"})   # same name+labels, other kind


# ---------------------------------------------------------------------------
# exporters: JSONL schema, prometheus, console
# ---------------------------------------------------------------------------

def test_jsonl_schema_roundtrip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    sink = JsonlSink(path)
    sink.write("train_step", {"step": 0, "loss": 2.5, "wall_s": 0.01})
    sink.write("kfac_step", {"step": 0, "stages": {"estimate_stats": 1e-3}})
    sink.write("refresh", {"mode": "serial", "wall_s": 2e-3})
    sink.write("serve_request", {"uid": 7, "n_tokens": 12,
                                 "ttft_ms": 30.0})
    sink.write("serve_run", {"steps": 40, "completed": 3})
    sink.write("custom_event", {"anything": [1, 2.5, "x", None]})
    sink.close()
    events = read_jsonl(path)
    assert [e["event"] for e in events] == [
        "train_step", "kfac_step", "refresh", "serve_request",
        "serve_run", "custom_event"]
    assert all(e["v"] == 1 and e["ts"] > 0 for e in events)

    # the CI gate accepts the file and counts types
    from benchmarks import obs_check
    counts = obs_check.check(path, expect=["train_step", "refresh"])
    assert counts["train_step"] == 1
    with pytest.raises(ValueError, match="never emitted"):
        obs_check.check(path, expect=["no_such_event"])


def test_jsonl_rejects_bad_events(tmp_path):
    assert validate_event({"v": 1, "event": "refresh", "ts": 1.0,
                           "mode": "serial", "wall_s": 0.1})
    with pytest.raises(ValueError, match="schema v"):
        validate_event({"v": 99, "event": "x", "ts": 1.0})
    with pytest.raises(ValueError, match="missing required"):
        validate_event({"v": 1, "event": "train_step", "ts": 1.0})
    with pytest.raises(ValueError, match="non-finite"):
        validate_event({"v": 1, "event": "x", "ts": 1.0,
                        "bad": float("inf")})
    # a malformed line fails read_jsonl with its line number
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"v": 1, "event": "refresh", "ts": 1.0,
                            "mode": "serial", "wall_s": 0.1}) + "\n")
        f.write("{\"v\": 1}\n")
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        read_jsonl(path)


def test_prometheus_and_console_render():
    reg = Registry()
    reg.counter("serve/steps").inc(5)
    reg.gauge("train/loss", {"arch": "mlp"}).set(1.25)
    h = reg.histogram("span_s", {"span": "kfac/estimate_stats"})
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    prom = prometheus_text(reg)
    assert "# TYPE repro_serve_steps counter" in prom
    assert "repro_serve_steps 5" in prom
    assert 'repro_train_loss{arch="mlp"} 1.25' in prom
    assert 'repro_span_s_count{span="kfac/estimate_stats"} 3' in prom
    assert 'quantile="0.5"' in prom
    text = console_summary(reg, title="t")
    assert "[t] serve/steps = 5" in text
    assert "span_s{span=kfac/estimate_stats}" in text and "p99" in text


# ---------------------------------------------------------------------------
# the disabled-parity pin: training
# ---------------------------------------------------------------------------

def _jit_cache_sizes(pipe):
    out = {"stats": pipe._stats._cache_size(),
           "update": pipe._update._cache_size(),
           "update3": pipe._update3._cache_size(),
           "refresh": pipe._refresh._cache_size(),
           "lambda": pipe._lambda._cache_size()}
    return out


def test_training_disabled_bitwise_parity(tmp_path):
    """Enabled-vs-disabled training is bitwise identical (params AND the
    full scalar history) and compiles the same number of programs per
    stage — telemetry must never touch the jitted computation."""
    steps = 8
    results, cache_sizes = [], []
    for enabled in (False, True):
        mlp, params, data = _problem()
        ocfg = ObsConfig(enabled=enabled,
                         jsonl_path=(str(tmp_path / "train.jsonl")
                                     if enabled else ""))
        cfg = KFACConfig(lambda_init=3.0, t1=2, t2=4, t3=3, eta=1e-5,
                         obs=ocfg)
        obs = Obs(ocfg)
        opt = optimizers.kfac(mlp, cfg, family="bernoulli", obs=obs)
        tr = Trainer(mlp, opt, TrainConfig(steps=steps, seed=0,
                                           log_every=10 ** 9, obs=ocfg),
                     obs=obs)
        out = tr.fit(params, data, steps, log=lambda *_: None)
        obs.close()
        results.append(out)
        # the Optimizer wraps the pipeline's bound methods
        pipe = opt.update.__self__
        cache_sizes.append(_jit_cache_sizes(pipe))

    off, on = results
    for a, b in zip(jax.tree.leaves(off["params"]),
                    jax.tree.leaves(on["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert off["history"] == on["history"]
    assert cache_sizes[0] == cache_sizes[1], (
        "obs=enabled changed what got compiled")

    # and the enabled run really did log the pipeline + trainer planes
    events = read_jsonl(str(tmp_path / "train.jsonl"))
    kinds = {e["event"] for e in events}
    assert {"train_step", "kfac_step", "refresh"} <= kinds
    ks = [e for e in events if e["event"] == "kfac_step"]
    assert len(ks) == steps
    assert all("estimate_stats" in e["stages"] for e in ks)


def test_trainer_counts_rejected_steps():
    """The rejected-step counter is live even with obs disabled (counters
    are plain host ints feeding run summaries)."""
    mlp, params, data = _problem()
    cfg = KFACConfig(lambda_init=3.0, t3=3, eta=1e-5)
    opt = optimizers.kfac(mlp, cfg, family="bernoulli")
    obs = Obs()                              # disabled
    tr = Trainer(mlp, opt, TrainConfig(steps=4, seed=0, log_every=10 ** 9),
                 obs=obs)
    bad = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), params)
    tr.fit(bad, data, 2, log=lambda *_: None)
    assert obs.registry.counter("train/rejected_steps").value >= 1
    assert obs.registry.counter("train/steps").value >= 2


# ---------------------------------------------------------------------------
# the disabled-parity pin: serving
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    from repro.configs import get_reduced_config
    from repro.models.lm import LM
    cfg = get_reduced_config("smollm-135m")
    lm = LM(cfg)
    return lm, lm.init_params(jax.random.PRNGKey(0)), cfg


def _serve_reqs(cfg):
    from repro.serving.server import Request
    return [Request(uid=u, prompt=[(5 * u + j) % cfg.vocab_size
                                   for j in range(3 + u % 3)], max_new=5)
            for u in range(5)]


def test_serving_disabled_bitwise_parity(smollm, tmp_path):
    from repro.serving.server import Engine
    lm, params, cfg = smollm
    outs, caches, reports = [], [], []
    for enabled in (False, True):
        obs = Obs(ObsConfig(enabled=enabled,
                            jsonl_path=(str(tmp_path / "serve.jsonl")
                                        if enabled else "")))
        eng = Engine(lm, params, batch_slots=2, max_len=24, page_size=4,
                     num_pages=8, obs=obs)
        reqs = _serve_reqs(cfg)
        reports.append(eng.run(reqs))
        obs.close()
        outs.append([r.out for r in reqs])
        caches.append(eng._step._cache_size())

    assert outs[0] == outs[1], "telemetry changed the served tokens"
    assert caches[0] == caches[1], "obs=enabled recompiled the decode step"
    off, on = reports
    assert (off.steps, len(off.completed)) == (on.steps, len(on.completed))
    assert off.preemptions == on.preemptions
    # latency aggregates exist only on the enabled run
    assert off.ttft_p50_ms is None and on.ttft_p50_ms > 0
    assert on.decode_p50_ms > 0

    events = read_jsonl(str(tmp_path / "serve.jsonl"))
    kinds = [e["event"] for e in events]
    assert kinds.count("serve_request") == len(on.completed)
    assert kinds[-1] == "serve_run"
    req_evs = [e for e in events if e["event"] == "serve_request"]
    assert all(e["n_tokens"] > 0 and e["ttft_ms"] > 0 for e in req_evs)


def test_engine_counters_feed_report(smollm):
    """RunReport preemption/eviction aggregates are per-run counter deltas
    — a warmup run on the same engine must not leak into them."""
    from repro.serving.server import Engine
    lm, params, cfg = smollm
    eng = Engine(lm, params, batch_slots=3, max_len=24, page_size=2,
                 num_pages=7)                # tiny pool -> eviction pressure
    first = eng.run(_serve_reqs(cfg))
    assert first.preemptions > 0 and first.evictions > 0
    c = eng.obs.registry.counter("serve/preemptions").value
    eng.reset()
    second = eng.run(_serve_reqs(cfg))
    # deltas, not lifetime totals:
    assert second.preemptions == first.preemptions
    assert eng.obs.registry.counter("serve/preemptions").value == 2 * c
    assert eng.obs.registry.counter(
        "serve/sampled", {"mode": "greedy"}).value > 0


# ---------------------------------------------------------------------------
# the latency split (shared with bench_serving)
# ---------------------------------------------------------------------------

def test_latency_tracker_split_and_percentiles():
    lat = RequestLatencyTracker()
    lat.on_submit(1, t=0.0)
    assert lat.on_emit(1, t=0.25) == ("ttft", 0.25)
    assert lat.on_emit(1, t=0.30) == ("decode", pytest.approx(0.05))
    lat.on_submit(2, t=0.1)
    assert lat.on_emit(2, t=0.5)[0] == "ttft"
    assert lat.on_emit(2, t=0.6)[0] == "decode"
    with pytest.raises(ValueError, match="before on_submit"):
        lat.on_emit(99)
    p = lat.percentiles()
    ttft_ms = [250.0, 400.0]
    dec_ms = [50.0, 100.0]
    assert p["ttft_p50_ms"] == pytest.approx(np.percentile(ttft_ms, 50))
    assert p["ttft_p99_ms"] == pytest.approx(np.percentile(ttft_ms, 99))
    assert p["decode_p50_ms"] == pytest.approx(np.percentile(dec_ms, 50))
    assert lat.n_tokens == 4
    lenient = RequestLatencyTracker()
    assert lenient.percentiles_or_none()["ttft_p50_ms"] is None


def test_latency_tracker_mirrors_registry():
    reg = Registry()
    lat = RequestLatencyTracker(reg)
    lat.on_submit(0, t=0.0)
    lat.on_emit(0, t=0.2)
    lat.on_emit(0, t=0.3)
    assert reg.histogram("serve/ttft_ms").snapshot()["count"] == 1
    assert reg.histogram("serve/decode_gap_ms").snapshot()["count"] == 1


# ---------------------------------------------------------------------------
# OverlapController telemetry: cancelled buffers counted, not dropped
# ---------------------------------------------------------------------------

def test_overlap_controller_counts_cancel_and_forced_commit():
    from repro.distributed.overlap import OverlapController

    class _Stuck:
        def is_ready(self):
            return False

    @dataclasses.dataclass(frozen=True)
    class MiniState:
        factors: object
        gamma: object
        inv: object
        inv_pending: object
        staleness: object

        def replace(self, **kw):
            return dataclasses.replace(self, **kw)

    obs = Obs()                              # disabled: counters still live
    ctl = OverlapController(lambda f, g, p: {"w": _Stuck()}, bound=3,
                            obs=obs)
    state = MiniState(factors={}, gamma=1.0, inv={"w": 0},
                      inv_pending={"w": 0}, staleness=jnp.int32(0))

    # dispatch at 3, cancel at 5 (T2 sweep): age 2 counted, not discarded
    state = ctl.on_refresh_stage(state, step=3, due=True)
    assert ctl.pending is not None
    ctl.cancel(step=5)
    assert ctl.pending is None
    assert ctl.n_cancelled == 1 and ctl.cancelled_age_steps == 2
    assert obs.registry.counter("overlap/cancelled_buffers").value == 1
    assert obs.registry.histogram(
        "overlap/cancelled_buffer_s").snapshot()["count"] == 1

    # dispatch at 6, never ready -> forced (blocking) commit at 9
    state = ctl.on_refresh_stage(state, step=6, due=True)
    state = ctl.on_refresh_stage(state, step=7, due=False)
    assert ctl.last_staleness == 1
    state = ctl.on_refresh_stage(state, step=8, due=False)
    state = ctl.on_refresh_stage(state, step=9, due=True)
    assert ctl.n_commits == 1 and ctl.n_forced_commits == 1
    assert ctl.last_forced and ctl.last_refresh_s > 0
    assert obs.registry.counter("overlap/forced_commits").value == 1
    assert ctl.last_staleness == 0


# ---------------------------------------------------------------------------
# thread safety: serving engine + daemon writers share one registry/sink
# ---------------------------------------------------------------------------

def test_registry_thread_safety():
    reg = Registry()
    c = reg.counter("n")
    h = reg.histogram("v")
    n_threads, n_iter = 8, 500

    def work():
        for i in range(n_iter):
            c.inc()
            h.observe(float(i))
            reg.counter("n")                 # concurrent get-or-create

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * n_iter
    assert h.snapshot()["count"] == n_threads * n_iter


def test_jsonl_sink_concurrent_writers(tmp_path):
    path = str(tmp_path / "conc.jsonl")
    sink = JsonlSink(path)
    n_threads, n_iter = 6, 200

    def work(tid):
        for i in range(n_iter):
            sink.write("custom", {"tid": tid, "i": i})

    ts = [threading.Thread(target=work, args=(t,))
          for t in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    sink.close()
    events = read_jsonl(path)                # every line parses + validates
    assert len(events) == n_threads * n_iter


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def test_obs_config_defaults_disabled():
    assert KFACConfig().obs.enabled is False
    assert TrainConfig().obs.enabled is False
    o = Obs()
    assert not o.enabled and o.sink is None
    # disabled span is the shared no-op (no allocation per call)
    s1, s2 = o.span("a"), o.span("b")
    assert s1 is s2
    with s1:
        pass
    assert s1.seconds is None
