"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import factors as F
from repro.core import inverse as INV
from repro.core.damping import lambda_update
from repro.models.head import _pick_chunk

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

dims = st.integers(min_value=2, max_value=12)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _spd(seed, d, scale=1.0):
    m = jax.random.normal(jax.random.PRNGKey(seed), (d, d))
    return m @ m.T / d * scale + 0.05 * jnp.eye(d)


@given(seeds, dims)
def test_outer_sum_psd(seed, d):
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, d))
    a = F.outer_sum(x, "full", 1)
    w = np.linalg.eigvalsh(np.asarray(a))
    assert w.min() > -1e-4 * max(1.0, w.max())
    np.testing.assert_allclose(a, a.T, rtol=1e-5, atol=1e-6)


@given(seeds, dims, st.floats(min_value=0.1, max_value=100.0))
def test_pi_scale_equivariance(seed, d, c):
    """pi(cA, G) = sqrt(c) pi(A, G) — trace-norm homogeneity (S6.3)."""
    a, g = _spd(seed, d), _spd(seed + 1, d)
    p1 = INV.pi_trace(a, "full", d, g, "full", d)
    p2 = INV.pi_trace(c * a, "full", d, g, "full", d)
    np.testing.assert_allclose(p2, np.sqrt(c) * p1, rtol=1e-4)


@given(seeds, dims, st.floats(min_value=0.01, max_value=10.0))
def test_inverse_is_inverse(seed, d, gamma):
    a = _spd(seed, d)
    inv = INV.factor_inverse(a, "full", gamma, method="eigh")
    np.testing.assert_allclose(
        inv @ (a + gamma * jnp.eye(d)), jnp.eye(d), atol=5e-3)


@given(seeds, dims, dims)
def test_precondition_linear(seed, da, dg):
    """F⁻¹(aV1 + bV2) = a F⁻¹V1 + b F⁻¹V2."""
    from repro.core.tags import LayerMeta
    meta = LayerMeta("l", ("w",), d_in=da, d_out=dg)
    inv = {"a_inv": jnp.linalg.inv(_spd(seed, da)),
           "g_inv": jnp.linalg.inv(_spd(seed + 1, dg))}
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 2))
    v1 = jax.random.normal(k1, (da, dg))
    v2 = jax.random.normal(k2, (da, dg))
    lhs = INV.apply_block_inverse(meta, inv, 2.0 * v1 - 3.0 * v2)
    rhs = (2.0 * INV.apply_block_inverse(meta, inv, v1)
           - 3.0 * INV.apply_block_inverse(meta, inv, v2))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-4)


@given(seeds)
def test_decay_eps_bounds(seed):
    k = jnp.int32(seed % 10_000 + 1)
    eps = F.decay_eps(k, 0.95)
    assert 0.0 <= float(eps) <= 0.95


@given(st.floats(min_value=-5, max_value=5),
       st.floats(min_value=1e-6, max_value=1e6))
def test_lambda_update_bounded(rho, lam):
    out = float(lambda_update(jnp.float32(lam), jnp.float32(rho), 0.9))
    assert 1e-8 <= out <= 1e8


@given(st.integers(min_value=1, max_value=4096),
       st.integers(min_value=1, max_value=512))
def test_pick_chunk_divides(n, target):
    c = _pick_chunk(n, target)
    assert n % c == 0 and 1 <= c <= max(1, min(n, target))


@given(seeds, st.integers(min_value=2, max_value=6),
       st.integers(min_value=2, max_value=4))
def test_blend_converges_to_new(seed, d, steps):
    """Repeated blending with eps=0 returns exactly the new value."""
    old = {"a": jnp.ones((d, d))}
    new = {"a": jnp.full((d, d), 3.0)}
    out = F.blend(old, new, 0.0)
    np.testing.assert_allclose(out["a"], new["a"])
    out2 = F.blend(old, new, 1.0)
    np.testing.assert_allclose(out2["a"], old["a"])


@given(seeds, dims)
def test_ns_vs_eigh_property(seed, d):
    a = _spd(seed, d) + jnp.eye(d)
    e = INV.factor_inverse(a, "full", 0.3, method="eigh")
    n = INV.factor_inverse(a, "full", 0.3, method="ns", iters=30)
    np.testing.assert_allclose(e, n, rtol=5e-3, atol=5e-4)


def _conditioned_spd(seed, d, cond):
    """SPD matrix with eigenvalues log-spaced over exactly [1/cond, 1]."""
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed), (d, d)))
    w = jnp.logspace(-np.log10(cond), 0.0, d)
    return jnp.einsum("ij,j,kj->ik", q, w, q)


@given(seeds, dims, st.floats(min_value=1.0, max_value=1e6))
def test_ns_vs_eigh_under_conditioning(seed, d, cond):
    """ns/eigh inverses must agree across 6 decades of factor conditioning
    (the damping keeps the *damped* matrix NS-friendly even when the raw
    factor is nearly singular)."""
    a = _conditioned_spd(seed, d, cond)
    e = INV.factor_inverse(a, "full", 0.1, method="eigh")
    n = INV.factor_inverse(a, "full", 0.1, method="ns", iters=40)
    np.testing.assert_allclose(e, n, rtol=5e-3, atol=5e-4)


@given(seeds, dims, st.floats(min_value=1e-6, max_value=1e3),
       st.floats(min_value=1.0, max_value=1e6))
def test_add_damp_preserves_psd(seed, d, damp, cond):
    """_add_damp shifts the spectrum up by exactly `damp`: the damped factor
    stays PSD with min eigenvalue >= damp (up to float tolerance)."""
    a = _conditioned_spd(seed, d, cond)
    damped = INV._add_damp(a, "full", jnp.float32(damp))
    w = np.linalg.eigvalsh(np.asarray(damped))
    assert w.min() >= damp * (1 - 1e-3) - 1e-6, (w.min(), damp)
    # block/diag kinds damp each entry/block identically
    diag = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 1), (d,)))
    ddiag = INV._add_damp(diag, "diag", jnp.float32(damp))
    assert float(jnp.min(ddiag - diag)) >= damp * (1 - 1e-3) - 1e-6


@given(seeds, dims, dims, st.floats(min_value=0.01, max_value=10.0))
def test_eigen_matches_eigh_path_property(seed, da, dg, gamma):
    """EKFAC invariant: with s initialized from the exact factor eigenvalues
    (eigen_state at refresh), the eigenbasis apply equals the eigh damped
    factor-inverse apply for any factor pair and damping."""
    from repro.core.tags import LayerMeta
    meta = LayerMeta("l", ("w",), d_in=da, d_out=dg)
    a, g = _spd(seed, da), _spd(seed + 1, dg)
    inv = INV.damped_pair_inverse(meta, a, g, gamma, method="eigh")
    eig = INV.eigen_pair_state(meta, a, g, gamma)
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (da, dg))
    want = INV.apply_block_inverse(meta, inv, v)
    got = INV.apply_eigen(meta, eig, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# KFC convolution blocks (Grosse & Martens 1602.01407)
# ---------------------------------------------------------------------------

conv_channels = st.integers(min_value=1, max_value=5)
conv_taps = st.integers(min_value=1, max_value=4)
conv_strides = st.integers(min_value=1, max_value=3)
conv_pads = st.sampled_from(["SAME", "VALID"])


def _conv_block(c, k, s, pad, d_out=4, bias=True, cfg=None):
    from repro.configs.base import KFACConfig
    from repro.core import blocks as B
    from repro.models.conv import conv_meta
    meta = conv_meta("c", ("w",), spatial=(k,), stride=(s,), c_in=c,
                     d_out=d_out, padding=pad, bias=bias)
    return B.resolve(meta)(meta, cfg or KFACConfig())


@given(seeds, conv_channels, conv_taps, conv_strides, conv_pads)
def test_conv_a_factor_psd(seed, c, k, s, pad):
    """The KFC A-factor (spatially-averaged patch second moment, with the
    homogeneous bias coordinate) is symmetric PSD for any patch tensor."""
    blk = _conv_block(c, k, s, pad)
    t = k + 5                     # ensure at least one output position
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, t, c))
    a = blk.stats_contrib({"cx": x},
                          jnp.zeros((3, blk.meta.d_out)), {}, 3)["a"]
    assert a.shape == (blk.meta.a_dim, blk.meta.a_dim)
    np.testing.assert_allclose(a, a.T, rtol=1e-5, atol=1e-6)
    w = np.linalg.eigvalsh(np.asarray(a))
    assert w.min() > -1e-4 * max(1.0, w.max())


@given(seeds, conv_channels, conv_taps, conv_strides, conv_pads)
def test_patch_extraction_matches_lax(seed, c, k, s, pad):
    """extract_patches (tap-major) equals jax.lax.conv_general_dilated_patches
    (channel-major) up to the documented (k, c) transpose, and both equal a
    per-window numpy gather."""
    from repro.kernels.patch_factor import conv_pad_amounts
    from repro.models.conv import conv_out_len, extract_patches
    t = k + 6
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, t, c))
    mine = extract_patches(x, (k,), (s,), pad)
    theirs = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(k,), window_strides=(s,), padding=pad,
        dimension_numbers=("NWC", "WIO", "NWC"))
    t_out = conv_out_len(t, k, s, pad)
    assert mine.shape == (2, t_out, k * c)
    np.testing.assert_allclose(
        mine.reshape(2, t_out, k, c),
        jnp.swapaxes(theirs.reshape(2, t_out, c, k), -1, -2),
        rtol=1e-6, atol=1e-7)
    lo, hi = conv_pad_amounts(t, k, s, pad)
    xp = np.pad(np.asarray(x), ((0, 0), (lo, hi), (0, 0)))
    want = np.stack([xp[:, i * s:i * s + k, :].reshape(2, k * c)
                     for i in range(t_out)], axis=1)
    np.testing.assert_allclose(mine, want, rtol=1e-6, atol=1e-7)


@given(seeds, st.integers(min_value=2, max_value=4), conv_taps,
       st.floats(min_value=0.01, max_value=10.0))
def test_conv_eigen_matches_eigh_after_refresh(seed, c, k, gamma):
    """ConvKronecker inherits the EKFAC invariant: right after a refresh the
    eigenbasis apply equals the eigh damped-inverse apply on factors built
    from real patch statistics (bias row included)."""
    from repro.configs.base import KFACConfig
    blk = _conv_block(c, k, 1, "SAME", d_out=3,
                      cfg=KFACConfig(inv_mode="eigen"))
    m = blk.meta
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, k + 6, c))
    cot = jax.random.normal(jax.random.PRNGKey(seed + 1),
                            (4, k + 6, m.d_out)) / 16.0
    fac = blk.stats_contrib({"cx": x}, cot, {}, 8)
    fac = {"a": fac["a"] + 0.05 * jnp.eye(m.a_dim),
           "g": fac["g"] + 0.05 * jnp.eye(m.g_dim)}
    inv = blk.damped_inverse(fac, gamma, method="eigh")
    eig = blk.eigen_state(fac, gamma)
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (m.a_dim, m.g_dim))
    np.testing.assert_allclose(blk.precondition_eigen(eig, v),
                               blk.precondition(inv, v),
                               rtol=1e-4, atol=1e-4)


@given(seeds, dims, dims, st.floats(min_value=0.0, max_value=1.0))
def test_eigen_rescale_fixed_point(seed, da, dg, eps):
    """s is a fixed point of eigen_rescale exactly when the squared rotated
    gradient equals s (the EMA's stationary condition), for any decay."""
    from repro.core.tags import LayerMeta
    meta = LayerMeta("l", ("w",), d_in=da, d_out=dg)
    eig = INV.eigen_pair_state(meta, _spd(seed, da), _spd(seed + 1, dg), 0.3)
    t = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 2), (da, dg)))
    # a gradient whose rotation is exactly sqrt(s): rotate sqrt(s) back out
    g_fix = INV.rotate_eigen(meta, eig["qa"], eig["qg"], jnp.sqrt(eig["s"]),
                             adjoint=False)
    out = INV.eigen_rescale(meta, eig, g_fix, eps)
    np.testing.assert_allclose(out["s"], eig["s"], rtol=1e-3, atol=1e-4)
    # and blending toward a different target moves s monotonically toward it
    g_other = INV.rotate_eigen(meta, eig["qa"], eig["qg"], t, adjoint=False)
    out2 = INV.eigen_rescale(meta, eig, g_other, eps)
    np.testing.assert_allclose(out2["s"], eps * eig["s"] + (1 - eps) * t ** 2,
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# distributed refresh plan (repro.distributed.plan)
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=1.0, max_value=1e6), min_size=1,
                max_size=64),
       st.integers(min_value=1, max_value=8))
def test_refresh_plan_balance_bounded(costs, n_bins):
    """LPT bin-packing invariant: every block assigned exactly once, and
    no shard exceeds the lightest shard by more than one block's cost —
    so the max/min device cost ratio is bounded by
    (min + max_item) / min whenever every shard is loaded."""
    from repro.distributed.plan import RefreshPlan, bin_pack

    named = {f"b{i}": c for i, c in enumerate(costs)}
    owners = bin_pack(named, n_bins)
    assert sorted(owners) == sorted(named)          # full coverage, no dups
    assert all(0 <= b < n_bins for b in owners.values())

    plan = RefreshPlan(n_shards=n_bins, owners=owners, costs=named)
    loads = plan.shard_costs()
    assert max(loads) - max(named.values()) <= min(loads) + 1e-6 * max(loads)
    # critical path never exceeds the serial cost, and with at least as
    # many blocks as bins every bin is loaded and the ratio bound holds
    assert plan.parallel_cost() <= plan.serial_cost() + 1e-6
    if len(named) >= n_bins:
        loaded = [c for c in loads if c > 0]
        assert len(loaded) == n_bins
        assert plan.balance_ratio() <= \
            (min(loaded) + max(named.values())) / min(loaded) + 1e-6


@given(st.lists(st.floats(min_value=1.0, max_value=1e6), min_size=1,
                max_size=32),
       st.integers(min_value=1, max_value=8))
def test_refresh_plan_deterministic(costs, n_bins):
    """The plan is a pure function of (costs, n_bins) — insertion order of
    the cost mapping must not matter (devices must agree on ownership)."""
    from repro.distributed.plan import bin_pack

    named = {f"b{i}": c for i, c in enumerate(costs)}
    rev = dict(reversed(list(named.items())))
    assert bin_pack(named, n_bins) == bin_pack(rev, n_bins)
