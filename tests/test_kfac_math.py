"""K-FAC math correctness: the paper's core identities on small matrices."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import factors as F
from repro.core import inverse as INV
from repro.core import tridiag as TRI
from repro.core.tags import LayerMeta
from repro.models.mlp import MLP


def _spd(key, d, scale=1.0):
    m = jax.random.normal(jax.random.PRNGKey(key), (d, d))
    return m @ m.T / d * scale + 0.1 * jnp.eye(d)


# ---------------------------------------------------------------------------
# S4.2: block-diagonal inverse = Kronecker of factor inverses
# ---------------------------------------------------------------------------

def test_block_inverse_matches_dense_kron():
    da, dg = 5, 4
    a, g = _spd(0, da), _spd(1, dg)
    meta = LayerMeta("l", ("w",), d_in=da, d_out=dg)
    gamma = 0.3
    inv = INV.damped_pair_inverse(meta, a, g, gamma, method="eigh")
    v = jax.random.normal(jax.random.PRNGKey(2), (da, dg))
    got = INV.apply_block_inverse(meta, inv, v)

    # dense reference: F = A ⊗ G with factored damping.  Row-major flatten of
    # V (da, dg) matches kron(A, G) (i.e. column-stacked vec of the paper's
    # (dg, da) layout).
    pi = INV.pi_trace(a, "full", da, g, "full", dg)
    a_d = a + pi * gamma * jnp.eye(da)
    g_d = g + gamma / pi * jnp.eye(dg)
    f = jnp.kron(a_d, g_d)
    want = (jnp.linalg.inv(f) @ v.reshape(-1)).reshape(da, dg)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_blocked_factor_matches_blockdiag_dense():
    """TP-blocked factors = block-diagonal approximation of the full factor."""
    d, nb = 8, 2
    x = jax.random.normal(jax.random.PRNGKey(3), (32, d))
    full = F.outer_sum(x, "full", 1)
    blocked = F.outer_sum(x, "block", nb)
    for b in range(nb):
        sl = slice(b * d // nb, (b + 1) * d // nb)
        np.testing.assert_allclose(blocked[b], full[sl, sl], rtol=1e-5)


def test_ns_inverse_matches_eigh():
    a = _spd(4, 16) + jnp.eye(16)
    inv_e = INV.factor_inverse(a, "full", 0.5, method="eigh")
    inv_n = INV.factor_inverse(a, "full", 0.5, method="ns", iters=25)
    np.testing.assert_allclose(inv_e, inv_n, rtol=1e-3, atol=1e-4)


def test_ns_hot_start():
    a = _spd(5, 12) + jnp.eye(12)
    cold = INV.factor_inverse(a, "full", 0.2, method="eigh")
    a2 = a + 0.01 * _spd(6, 12)           # slowly-drifting factor
    hot = INV.factor_inverse(a2, "full", 0.2, method="ns", iters=6, prev=cold)
    want = INV.factor_inverse(a2, "full", 0.2, method="eigh")
    np.testing.assert_allclose(hot, want, rtol=1e-3, atol=1e-4)


def test_pi_trace_formula():
    """pi = sqrt((trA/dA)/(trG/dG)) — S6.3."""
    a, g = _spd(7, 6), _spd(8, 3)
    pi = INV.pi_trace(a, "full", 6, g, "full", 3)
    want = jnp.sqrt((jnp.trace(a) / 6) / (jnp.trace(g) / 3))
    np.testing.assert_allclose(pi, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# Appendix B: (A⊗B − C⊗D)⁻¹ application
# ---------------------------------------------------------------------------

def test_appb_kron_difference_inverse():
    da, dg = 4, 3
    a, b = _spd(9, da, 2.0), _spd(10, dg, 2.0)
    # make C, D small enough that A⊗B − C⊗D stays PD
    c, d = 0.1 * _spd(11, da), 0.1 * _spd(12, dg)
    a_is = TRI._inv_sqrt(a)
    b_is = TRI._inv_sqrt(b)
    s1, e1 = jnp.linalg.eigh(a_is @ c @ a_is)
    s2, e2 = jnp.linalg.eigh(b_is @ d @ b_is)
    cache = {"k1": a_is @ e1, "k2": b_is @ e2, "s1": s1, "s2": s2}
    x = jax.random.normal(jax.random.PRNGKey(13), (dg, da))
    got = TRI._sigma_inv_apply(cache, x)
    dense = jnp.kron(a, b) - jnp.kron(c, d)
    want = (jnp.linalg.inv(dense) @ x.T.reshape(-1)).reshape(da, dg).T
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# S4.3: tridiagonal F̂⁻¹ = Ξᵀ Λ Ξ vs a dense construction
# ---------------------------------------------------------------------------

def _dense_tridiag_inverse(a_d, g_d, cross_a, cross_g):
    """Build F̂⁻¹ densely from the same damped factors via Ψ / Σ."""
    ell = len(a_d)
    blocks = [a.shape[0] * g.shape[0] for a, g in zip(a_d, g_d)]
    psi = []
    for i in range(ell - 1):
        pa = cross_a[i] @ jnp.linalg.inv(a_d[i + 1])
        pg = cross_g[i] @ jnp.linalg.inv(g_d[i + 1])
        psi.append(jnp.kron(pa, pg))
    sig = []
    for i in range(ell - 1):
        f_ii = jnp.kron(a_d[i], g_d[i])
        f_jj = jnp.kron(a_d[i + 1], g_d[i + 1])
        sig.append(f_ii - psi[i] @ f_jj @ psi[i].T)
    sig.append(jnp.kron(a_d[-1], g_d[-1]))
    n = sum(blocks)
    xi = jnp.eye(n)
    off = np.cumsum([0] + blocks)
    xi = np.array(xi)
    for i in range(ell - 1):
        xi[off[i]:off[i + 1], off[i + 1]:off[i + 2]] = -np.array(psi[i])
    lam = np.zeros((n, n))
    for i in range(ell):
        lam[off[i]:off[i + 1], off[i]:off[i + 1]] = np.array(
            jnp.linalg.inv(sig[i]))
    return jnp.array(xi.T @ lam @ xi)


def test_tridiag_apply_matches_dense():
    dims = [3, 4, 2, 3]
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    key = jax.random.PRNGKey(0)
    params = mlp.init_params(key, sparse=False)
    x = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (64, dims[0])).astype(
        jnp.float32)
    batch = {"x": x, "y": x[:, :dims[-1]] if dims[-1] != dims[0] else x}
    batch["y"] = x[:, :dims[-1]]

    # gather stats
    shapes = mlp.probe_shapes(jax.eval_shape(lambda b: b, batch))
    probes = mlp.make_probes(shapes)

    def f2(pr):
        (_, ls), aux = mlp.loss(params, pr, batch, jax.random.PRNGKey(2),
                                mode="collect")
        return ls, aux

    ls, vjp_fn, aux = jax.vjp(f2, probes, has_aux=True)
    (gp,) = vjp_fn(jnp.float32(1.0))
    recs = aux["recs"]
    n = x.shape[0]
    factors = {}
    for name, m in mlp.metas.items():
        factors[name] = {
            "a": F.outer_sum(recs[name]["a"], "full", 1) / n,
            "g": F.g_from_cotangent(gp[name], m, n)}
    factors["__cross__"] = TRI.cross_contrib(mlp, recs, gp, n)

    gamma = 0.7
    tri = TRI.precompute(mlp, factors, gamma, 0.0)
    vs = {name: jax.random.normal(jax.random.PRNGKey(3 + i),
                                  (mlp.metas[name].a_dim,
                                   mlp.metas[name].g_dim))
          for i, name in enumerate(mlp.layer_order)}
    got = TRI.apply(mlp, tri, vs)

    # dense reference with identically-damped factors
    a_d, g_d, cross_a, cross_g = [], [], [], []
    for name in mlp.layer_order:
        m = mlp.metas[name]
        a = factors[name]["a"]
        g = factors[name]["g"]
        pi = INV.pi_trace(a, "full", m.a_dim, g, "full", m.g_dim)
        a_d.append(a + pi * gamma * jnp.eye(m.a_dim))
        g_d.append(g + gamma / pi * jnp.eye(m.g_dim))
    for i in range(len(mlp.layer_order) - 1):
        cross_a.append(factors["__cross__"][f"a{i}"])
        cross_g.append(factors["__cross__"][f"g{i}"])
    f_inv = _dense_tridiag_inverse(a_d, g_d, cross_a, cross_g)
    vec = jnp.concatenate([vs[nm].reshape(-1) for nm in mlp.layer_order])
    want_flat = f_inv @ vec
    off = 0
    for name in mlp.layer_order:
        m = mlp.metas[name]
        sz = m.a_dim * m.g_dim
        want = want_flat[off:off + sz].reshape(m.a_dim, m.g_dim)
        np.testing.assert_allclose(got[name], want, rtol=2e-3, atol=2e-3)
        off += sz


# ---------------------------------------------------------------------------
# Lemma 4: E[g] = 0 under model-sampled targets (statistical check)
# ---------------------------------------------------------------------------

def test_lemma4_sampled_g_zero_mean():
    dims = [6, 5, 4]
    mlp = MLP(dims, loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (2000, dims[0]))
    batch = {"x": x, "y": jnp.zeros((2000, dims[-1]))}
    shapes = mlp.probe_shapes(jax.eval_shape(lambda b: b, batch))
    probes = mlp.make_probes(shapes)

    def f2(pr):
        (_, ls), aux = mlp.loss(params, pr, batch, jax.random.PRNGKey(7),
                                mode="collect")
        return ls

    gp = jax.grad(f2)(probes)
    for name, g in gp.items():
        mean = jnp.mean(jnp.abs(jnp.mean(g * 2000, axis=0)))
        scale = jnp.std(g * 2000) + 1e-9
        assert mean < 5 * scale / np.sqrt(2000), (name, mean, scale)


# ---------------------------------------------------------------------------
# Theorem 1: invariance to affine reparameterization (Omega transforms)
# ---------------------------------------------------------------------------

def test_invariance_to_input_transform():
    """K-FAC's update direction is invariant to an invertible affine
    transform of the inputs (Omega_0), up to the matching reparameterization
    — gradient descent is not."""
    dims = [4, 6, 3]
    key = jax.random.PRNGKey(0)
    omega = jax.random.normal(jax.random.PRNGKey(42), (4, 4)) * 0.5 + jnp.eye(4)

    def run(transform):
        mlp = MLP(dims, loss="gaussian")
        params = mlp.init_params(key, sparse=False)
        if transform:   # reparameterize W0 so the function is unchanged:
            # x' = x Omegaᵀ  =>  W0' = [Omega^{-T} W0w ; b0]
            w0 = params["W0"]
            w0w = jnp.linalg.solve(omega.T, w0[:-1])
            params = dict(params, W0=jnp.concatenate([w0w, w0[-1:]], 0))
        x = jax.random.normal(jax.random.PRNGKey(1), (512, 4))
        y = jax.random.normal(jax.random.PRNGKey(2), (512, 3))
        xin = x @ omega.T if transform else x
        batch = {"x": xin, "y": y}

        shapes = mlp.probe_shapes(jax.eval_shape(lambda b: b, batch))
        probes = mlp.make_probes(shapes)

        def floss(p, pr):
            (lt, ls), aux = mlp.loss(p, pr, batch, jax.random.PRNGKey(3),
                                     mode="collect")
            return (lt, ls), aux

        (lt, ls), vjp_fn, aux = jax.vjp(floss, params, probes, has_aux=True)
        grads, _ = vjp_fn((jnp.float32(1.0), jnp.float32(0.0)))
        _, gp = vjp_fn((jnp.float32(0.0), jnp.float32(1.0)))
        n = 512
        out = {}
        for name, m in mlp.metas.items():
            a = F.outer_sum(aux["recs"][name]["a"], "full", 1) / n
            g = F.g_from_cotangent(gp[name], m, n)
            # tiny isotropic damping (Thm 1 assumes damping negligible)
            inv = {"a_inv": jnp.linalg.inv(a + 1e-6 * jnp.eye(m.a_dim)),
                   "g_inv": jnp.linalg.inv(g + 1e-6 * jnp.eye(m.g_dim))}
            out[name] = INV.apply_block_inverse(m, inv, grads[f"W{name[5:]}"])
        return out, params

    u_base, p_base = run(False)
    u_tr, p_tr = run(True)
    # Theorem 1: zeta(theta† + delta†) = theta + delta. For W0 (weights part)
    # that means Omega^{-T}-transformed update rows must match.
    got = jnp.concatenate(
        [jnp.linalg.solve(omega.T, u_base["layer0"][:-1]),
         u_base["layer0"][-1:]], axis=0)
    np.testing.assert_allclose(u_tr["layer0"], got, rtol=5e-2, atol=5e-4)
    # layers above the transform are untouched
    np.testing.assert_allclose(u_tr["layer1"], u_base["layer1"], rtol=5e-2,
                               atol=5e-4)
