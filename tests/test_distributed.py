"""Distributed lowering tests — run in a subprocess with 8 fake CPU devices
(the main test process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced_config
    from repro.configs.base import KFACConfig
    from repro.core.kfac import KFAC
    from repro.launch.specs import train_batch_specs, rng_spec
    from repro.launch import hlo_cost
    from repro.configs.base import ShapeConfig
    from repro.models.lm import LM

    arch = sys_arch = "{arch}"
    multi_pod = {multi_pod}
    mesh = (jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else jax.make_mesh((4, 2), ("data", "model")))
    cfg = get_reduced_config(arch)
    shape = ShapeConfig("t", 32, 8, "train")
    kcfg = KFACConfig(max_factor_dim=64, inv_mode="{inv_mode}")
    lm = LM(cfg, kcfg, mesh, compute_dtype=jnp.bfloat16)
    opt = KFAC(lm, kcfg, mesh)
    params_abs = lm.abstract_params(jnp.float32)
    batch_abs = train_batch_specs(cfg, shape, mesh)
    state_abs = jax.eval_shape(opt.init, params_abs, batch_abs)
    state_sh = opt.state_shardings(state_abs, lm.param_shardings(), mesh)
    state_abs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_abs, state_sh)

    def train_step(state, params, batch, rng):
        state, grads, metrics = opt.stats_grads(state, params, batch, rng)
        state = opt.rescale_step(state, grads)   # no-op unless inv_mode=eigen
        params, state, um = opt.apply_update(state, params, grads, batch, rng)
        return params, state

    with mesh:
        lowered = jax.jit(train_step).lower(state_abs, params_abs, batch_abs,
                                            rng_spec(mesh))
        compiled = lowered.compile()
    res = hlo_cost.analyze(compiled.as_text())
    ag = [hlo_cost.shape_bytes(k) for k in res["top_collectives"]
          if k.startswith("all-gather")]
    print("RESULT" + json.dumps({{
        "flops": res["flops"], "coll": res["collectives"]["total"],
        "max_allgather": max(ag) if ag else 0,
        "n_devices": len(jax.devices())}}))
""")


def _run(arch: str, multi_pod: bool, inv_mode: str = "blkdiag"):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(arch=arch, multi_pod=multi_pod, inv_mode=inv_mode)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.distributed
@pytest.mark.parametrize("arch", ["llama3.2-1b", "granite-moe-1b-a400m"])
def test_single_pod_lowering(arch):
    res = _run(arch, multi_pod=False)
    assert res["n_devices"] == 8
    assert res["flops"] > 0


@pytest.mark.distributed
def test_multi_pod_lowering():
    res = _run("llama3.2-1b", multi_pod=True)
    assert res["n_devices"] == 8
    assert res["flops"] > 0


@pytest.mark.distributed
def test_eigen_mode_lowering():
    """inv_mode="eigen": eigen state shardings resolve (None entries pair
    with identity bases), stats→rescale→update lowers on the 8-device fake
    mesh, and no collective all-gathers a full eigenbasis — the rotations
    run against the local shards (hlo_cost's biggest all-gather site stays
    far below the largest (d, d) basis)."""
    res = _run("llama3.2-1b", multi_pod=False, inv_mode="eigen")
    assert res["n_devices"] == 8
    assert res["flops"] > 0
    assert res["coll"] > 0           # grad reductions must exist
    # per-instance gather bound: the FSDP weight-tile gathers in this
    # lowering are <= 32 KiB, while any stacked eigenbasis or eigenbasis
    # diagonal (e.g. the embed (256, 64) s, or a scanned (2, 2, 64, 64)
    # qa) is >= 64 KiB — gathering one would trip this (0 gathers is fine)
    assert res["max_allgather"] < 64 * 1024, res["max_allgather"]
