"""Distributed *numerics* tests — run in subprocesses with 8 fake CPU
devices (the main test process must keep seeing 1 device; cf.
tests/test_distributed.py, which checks lowering only).

Covers the distributed curvature service end to end on a real multi-device
mesh: the sharded block-parallel refresh is bitwise-identical to the
serial one, the async overlap mode trains under its staleness bound, and
K-FAC state survives an elastic pod-count change (8 -> 4 devices)
bit-for-bit through ``remesh_plan`` + ``reshard``.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import optimizers
    from repro.configs.base import KFACConfig
    from repro.data.pipeline import SyntheticAutoencoderData
    from repro.models.mlp import MLP

    assert len(jax.devices()) == 8, jax.devices()

    def problem(dims=(32, 16, 8, 16, 32), n=256):
        mlp = MLP(list(dims), nonlin="tanh", loss="bernoulli")
        params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
        data = SyntheticAutoencoderData(dims[0], 6, n, seed=7)
        return mlp, params, data

    def run(cfg, steps=8):
        mlp, params, data = problem()
        opt = optimizers.kfac(mlp, cfg, family="bernoulli")
        state = opt.init(params, data.batch(0))
        hist = []
        for step in range(steps):
            b = data.batch(step)
            rng = jax.random.fold_in(jax.random.PRNGKey(0), step)
            params, state, m = opt.update(None, state, params, b, rng)
            if opt.poll is not None:
                state = opt.poll(state)
            hist.append({k: float(v) for k, v in m.items()
                         if jnp.ndim(v) == 0})
        return params, state, hist

    def trees_equal(a, b, err=""):
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_array_equal(x, y, err_msg=err),
            a, b)
""")

_SHARDED_TAIL = textwrap.dedent("""
    cfg = KFACConfig(inv_mode="{inv_mode}", inverse_method="{method}",
                     lambda_init=1.0, t1=5, t2=4, t3=5, eta=1e-5)
    p1, s1, _ = run(cfg)
    p2, s2, _ = run(cfg.replace(refresh_mode="sharded"))
    trees_equal(p1, p2, "params")
    trees_equal(s1.inv, s2.inv, "inv")
    np.testing.assert_array_equal(s1.lam, s2.lam)
    # the refresh really is spread over the mesh: every loaded shard owns
    # strictly less than the whole cost
    from repro.distributed.refresh import build_sharded_refresh
    eng = optimizers.kfac(problem()[0], cfg, family="bernoulli").engine
    plan = build_sharded_refresh(eng).plan
    assert plan.n_shards == 8
    assert plan.parallel_cost() < plan.serial_cost()
    print("RESULT ok")
""")

_OVERLAP = _PRELUDE + textwrap.dedent("""
    cfg = KFACConfig(inv_mode="blkdiag", inverse_method="eigh",
                     lambda_init=1.0, t1=5, t2=0, t3=3, eta=1e-5,
                     refresh_mode="overlap")
    params, state, hist = run(cfg, steps=12)
    losses = [h["loss"] for h in hist]
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0], losses
    stale = [h.get("staleness", 0.0) for h in hist]
    assert max(stale) <= cfg.t3, stale
    assert state.inv_pending is not None
    print("RESULT ok")
""")

_ELASTIC = _PRELUDE + textwrap.dedent("""
    from repro.training.elastic import remesh_plan, reshard

    # an 8-device pod, FSDP(data=4) x TP(model=2)
    old_mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = KFACConfig(inv_mode="blkdiag", inverse_method="eigh",
                     lambda_init=1.0)
    mlp, params, data = problem()
    opt = optimizers.kfac(mlp, cfg, family="bernoulli")
    state = opt.init(params, data.batch(0))
    # populate every slot with real (non-symmetric-zero) values
    params, state, _ = opt.update(None, state, params, data.batch(0),
                                  jax.random.PRNGKey(1))

    rep = jax.sharding.NamedSharding(old_mesh, jax.sharding.PartitionSpec())
    param_sh = jax.tree.map(lambda _: rep, params)
    state_sh = opt.state_shardings(jax.eval_shape(lambda s: s, state),
                                   param_sh, old_mesh)
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    state8 = reshard(state, state_sh)

    # the pod shrank: rebuild on 4 of the 8 hosts' devices, same logical
    # layout — remesh_plan maps the PartitionSpec tree onto the new mesh
    new_mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    specs = jax.tree.map(lambda sh: sh.spec, state_sh)
    new_sh = remesh_plan(old_mesh, new_mesh, specs)
    state4 = reshard(state8, new_sh)

    used = {d for leaf in jax.tree.leaves(state4)
            for d in leaf.sharding.device_set}
    assert used <= set(jax.devices()[:4]), used
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), b),
        state4, host)
    # ... and back up to 8 devices, still bitwise
    state_back = reshard(state4, state_sh)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), b),
        state_back, host)
    print("RESULT ok")
""")


def _run_script(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert any(l.startswith("RESULT ok") for l in out.stdout.splitlines()), \
        out.stdout[-2000:]


@pytest.mark.distributed
@pytest.mark.parametrize("inv_mode,method", [("blkdiag", "eigh"),
                                             ("blkdiag", "ns"),
                                             ("eigen", "eigh")])
def test_sharded_refresh_bitwise_on_8_devices(inv_mode, method):
    """Acceptance: on a forced 8-device CPU mesh, refresh_mode="sharded"
    produces params and inverses bitwise-identical to "serial"."""
    _run_script(_PRELUDE + _SHARDED_TAIL.format(inv_mode=inv_mode,
                                                method=method))


@pytest.mark.distributed
def test_overlap_refresh_on_8_devices():
    """Async double-buffered refresh on the real 8-device mesh: trains,
    stays finite, staleness bounded by T3."""
    _run_script(_OVERLAP)


@pytest.mark.distributed
def test_elastic_remesh_8_to_4_bitwise():
    """Pod-count change: sharded K-FAC state restores onto a 4-device
    mesh (and back) through remesh_plan + reshard without changing a
    single bit."""
    _run_script(_ELASTIC)
