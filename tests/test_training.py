"""Trainer, checkpointing, fault tolerance, data determinism, serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.configs.base import KFACConfig, TrainConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData, SyntheticLMData
from repro.models.lm import LM
from repro.models.mlp import MLP
from repro.serving.server import Engine, Request
from repro.training.checkpoint import Checkpointer
from repro.training.trainer import Trainer
from repro.utils import tree as T


def test_data_determinism():
    d1 = SyntheticLMData(vocab=101, seq=8, global_batch=4, seed=3)
    d2 = SyntheticLMData(vocab=101, seq=8, global_batch=4, seed=3)
    b1, b2 = d1.batch(7), d2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(d1.batch(0)["labels"][:, :-1],
                                  d1.batch(0)["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.float32(3.5), "d": (jnp.ones(4), jnp.zeros(2))}}
    ck.save(5, tree, block=True)
    ck.save(9, T.tree_scale(tree, 2.0), block=True)
    assert ck.all_steps() == [5, 9]
    step, got = ck.restore(tree)
    assert step == 9
    np.testing.assert_allclose(got["a"], tree["a"] * 2.0)
    # keep=2 gc
    ck.save(11, tree, block=True)
    ck.save(12, tree, block=True)
    assert len(ck.all_steps()) == 2


def test_checkpoint_dict_state_migration(tmp_path):
    """Migration shim: a checkpoint written with the pre-dataclass *dict*
    optimizer state (schema 1) restores into the typed ``KFACState``
    template unchanged — field names and path keys line up."""
    import dataclasses
    import json as _json

    from repro import optimizers
    from repro.core.transform import KFACState

    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(16, 4, 64, seed=1)
    batch = data.batch(0)
    opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0),
                          family="bernoulli")
    state = opt.init(params, batch)
    params, state, _ = opt.update(None, state, params, batch,
                                  jax.random.PRNGKey(1))

    # the raw dict the pre-redesign optimizer kept as its state
    old_dict = {f.name: getattr(state, f.name)
                for f in dataclasses.fields(state)}
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, {"params": params, "state": old_dict}, block=True)

    # new writers stamp the schema version; rewrite the manifest without it
    # to simulate a genuinely old (schema-1, pre-version-field) checkpoint
    man_path = tmp_path / "step_00000003" / "manifest.json"
    man = _json.loads(man_path.read_text())
    assert man["schema"] == 4
    del man["schema"]
    man_path.write_text(_json.dumps(man))

    step, got = ck.restore({"params": params, "state": state})
    assert step == 3
    assert isinstance(got["state"], KFACState)
    for f in dataclasses.fields(state):
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(a, b, err_msg=f.name),
            getattr(state, f.name), getattr(got["state"], f.name))

    # a future schema must refuse to restore rather than misread
    man["schema"] = 99
    man_path.write_text(_json.dumps(man))
    try:
        ck.restore({"params": params, "state": state})
        assert False, "expected schema-version error"
    except ValueError as e:
        assert "schema" in str(e)


def test_checkpoint_v2_state_migration(tmp_path):
    """Schema 2 -> 3: pre-distributed-refresh checkpoints lack the
    ``staleness`` / ``inv_pending`` state leaves; restoring one into a v3
    template must keep the template's fresh-init values for exactly those
    fields and the checkpointed values for everything else."""
    import json as _json

    import numpy as _np

    from repro import optimizers
    from repro.configs.base import KFACConfig as _KC

    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(16, 4, 64, seed=1)
    batch = data.batch(0)
    opt = optimizers.kfac(mlp, _KC(lambda_init=1.0, refresh_mode="overlap"),
                          family="bernoulli")
    state = opt.init(params, batch)
    params, state, _ = opt.update(None, state, params, batch,
                                  jax.random.PRNGKey(1))
    state = state.replace(staleness=jnp.int32(2))   # non-default, must NOT
    #                                                 survive the migration

    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(4, {"params": params, "state": state}, block=True)

    # rewrite the checkpoint as a genuine v2: drop the v3-only leaves
    step_dir = tmp_path / "step_00000004"
    with np.load(step_dir / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files
                if "staleness" not in k.split("::")
                and "inv_pending" not in k.split("::")}
    assert len(flat) < len(jax.tree.leaves(state)) + len(
        jax.tree.leaves(params))
    _np.savez(step_dir / "arrays.npz", **flat)
    man = _json.loads((step_dir / "manifest.json").read_text())
    man["schema"] = 2
    (step_dir / "manifest.json").write_text(_json.dumps(man))

    template = opt.init(params, batch)
    step, got = ck.restore({"params": params, "state": template})
    assert step == 4
    # v3 fields fall back to the template (fresh-init) values ...
    assert int(got["state"].staleness) == 0
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(a, b),
        got["state"].inv_pending, template.inv_pending)
    # ... while the checkpointed fields restore verbatim
    np.testing.assert_array_equal(got["state"].lam, state.lam)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(a, b),
        got["state"].factors, state.factors)

    # a v3 checkpoint missing a NON-migration leaf must still hard-fail
    with np.load(step_dir / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files if "::lam" not in k}
    _np.savez(step_dir / "arrays.npz", **flat)
    try:
        ck.restore({"params": params, "state": template})
        assert False, "expected missing-leaf error"
    except KeyError as e:
        assert "lam" in str(e)


def test_checkpoint_v3_state_migration(tmp_path):
    """Schema 3 -> 4 is manifest-only (the optional ``curvature_bundle``
    pointer): a v3 checkpoint — same leaves, no pointer — must restore
    verbatim, with ``bundle_path`` reporting None; a future schema must
    refuse."""
    import json as _json

    from repro import optimizers

    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(16, 4, 64, seed=1)
    batch = data.batch(0)
    opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0),
                          family="bernoulli")
    state = opt.init(params, batch)
    params, state, _ = opt.update(None, state, params, batch,
                                  jax.random.PRNGKey(1))

    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(6, {"params": params, "state": state}, block=True)

    # rewrite as a genuine v3: stamp the old schema, drop any v4 key
    man_path = tmp_path / "step_00000006" / "manifest.json"
    man = _json.loads(man_path.read_text())
    man["schema"] = 3
    man.pop("curvature_bundle", None)
    man_path.write_text(_json.dumps(man))

    template = opt.init(params, batch)
    step, got = ck.restore({"params": params, "state": template})
    assert step == 6
    assert ck.bundle_path(6) is None
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(a, b),
        got["state"].factors, state.factors)
    np.testing.assert_array_equal(got["state"].lam, state.lam)

    # a pointer at a torn/absent bundle also reports None (never a path
    # that would fail to load)
    man["schema"] = 4
    man["curvature_bundle"] = "curvature/step_00000006"
    man_path.write_text(_json.dumps(man))
    assert ck.bundle_path(6) is None

    # a future schema must refuse to restore rather than misread
    man["schema"] = 5
    man_path.write_text(_json.dumps(man))
    try:
        ck.restore({"params": params, "state": template})
        assert False, "expected schema-version error"
    except ValueError as e:
        assert "schema" in str(e)


def test_checkpoint_refresh_mode_switch(tmp_path):
    """A schema-3 checkpoint written by a sync-mode run has no
    ``inv_pending`` leaves (the slot is None outside overlap).  Relaunching
    the same checkpoint dir with refresh_mode="overlap" — the natural
    adoption path — must restore, seeding the double buffer from the
    overlap template instead of KeyError-ing on the missing leaves."""
    from repro import optimizers
    from repro.configs.base import KFACConfig as _KC

    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(16, 4, 64, seed=1)
    batch = data.batch(0)

    serial = optimizers.kfac(mlp, _KC(lambda_init=1.0), family="bernoulli")
    state = serial.init(params, batch)
    params2, state, _ = serial.update(None, state, params, batch,
                                      jax.random.PRNGKey(1))
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"params": params2, "state": state}, block=True)

    overlap = optimizers.kfac(mlp, _KC(lambda_init=1.0,
                                       refresh_mode="overlap"),
                              family="bernoulli")
    template = overlap.init(params, batch)
    step, got = ck.restore({"params": params, "state": template})
    assert step == 1
    np.testing.assert_array_equal(got["state"].lam, state.lam)
    assert got["state"].inv_pending is not None
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(a, b),
        got["state"].inv_pending, template.inv_pending)
    # ... and the reverse direction (overlap ckpt -> sync template) just
    # drops the extra inv_pending leaves
    ck.save(2, {"params": params2, "state": got["state"]}, block=True)
    step, back = ck.restore({"params": params,
                             "state": serial.init(params, batch)})
    assert step == 2 and back["state"].inv_pending is None


def test_checkpoint_torn_write_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, {"x": jnp.ones(2)}, block=True)
    # simulate a torn checkpoint (no COMMIT)
    torn = tmp_path / "step_00000007"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 3


def test_trainer_end_to_end_and_restart(tmp_path):
    mlp = MLP([16, 8, 16], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)

    class Data:
        src = SyntheticAutoencoderData(16, 4, 64, seed=1)

        def batch(self, step):
            return self.src.batch(step, 64)

    kcfg = KFACConfig(lambda_init=1.0, t3=2, t1=2, t2=6)
    tcfg = TrainConfig(steps=8, checkpoint_every=4, log_every=100)
    ck = Checkpointer(str(tmp_path), async_save=False)
    tr = Trainer(mlp, KFAC(mlp, kcfg, family="bernoulli"), tcfg, None, ck)
    out = tr.fit(params, Data(), steps=8)
    assert len(out["history"]) == 8
    assert out["history"][-1]["loss"] < out["history"][0]["loss"] + 1e-3
    assert ck.latest_step() == 8

    # restart resumes from the checkpoint (no repeated work)
    tr2 = Trainer(mlp, KFAC(mlp, kcfg, family="bernoulli"), tcfg, None, ck)
    out2 = tr2.fit(params, Data(), steps=10)
    assert len(out2["history"]) == 2  # only steps 8..9


def test_trainer_nan_guard():
    mlp = MLP([8, 4, 8], loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)

    class Data:
        src = SyntheticAutoencoderData(8, 3, 32, seed=2)

        def batch(self, step):
            return self.src.batch(step, 32)

    kcfg = KFACConfig(lambda_init=1.0)
    tr = Trainer(mlp, KFAC(mlp, kcfg, family="bernoulli"),
                 TrainConfig(steps=2, log_every=100), None, None)
    # poison params -> first update must be skipped, lam raised, params kept
    bad = T.tree_scale(params, jnp.nan)
    out = tr.fit(bad, Data(), steps=1)
    assert float(out["state"]["lam"]) > kcfg.lambda_init


def test_serving_engine_completes():
    cfg = get_reduced_config("smollm-135m")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    eng = Engine(lm, params, batch_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=[3 + i, 5, 7], max_new=4) for i in range(3)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)


def test_serving_cache_zero_init():
    """Serving KV-cache init contract: the engine used to materialize the
    cache through the *weight* initializer with a hardcoded PRNGKey(0);
    that was only zero because every cache ParamDef carries init="zeros" —
    one cache leaf losing that flag would hand a fresh slot random garbage
    in positions it attends before writing.  Pin the contract itself: the
    cache is exactly zero at construction (now structural, RNG-free) for
    every rng_seed, and greedy decode does not depend on rng_seed."""
    cfg = get_reduced_config("smollm-135m")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))

    eng = Engine(lm, params, batch_slots=2, max_len=32, rng_seed=123)
    for leaf in jax.tree.leaves(eng.cache):
        assert float(jnp.abs(leaf).max()) == 0.0, leaf.shape

    # behavioral pin: identical greedy outputs under different rng seeds
    # (pre-fix, the init key was hardcoded — the cache contents could
    # never follow rng_seed, so any seed-dependence here means leakage)
    outs = []
    for seed in (0, 123):
        e = Engine(lm, params, batch_slots=2, max_len=32, rng_seed=seed)
        reqs = [Request(uid=0, prompt=[3, 5, 7], max_new=4)]
        e.run(reqs)
        outs.append(tuple(reqs[0].out))
    assert outs[0] == outs[1], outs


def test_elastic_reshard_identity():
    from repro.training.elastic import reshard
    tree = {"w": jnp.arange(8.0)}
    out = reshard(tree, {"w": None})
    np.testing.assert_array_equal(out["w"], tree["w"])
