"""Paper Fig. 7 analogue: objective improvement of one K-FAC update vs the
factored-Tikhonov strength gamma, with and without exact-F re-scaling."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import KFACConfig
from repro.core.kfac import KFAC
from benchmarks.benchlib import partially_train


def run():
    mlp, params, batch, state0 = partially_train(steps=12)
    rows = []
    for rescale in (True, False):
        best = (None, -1e9)
        for gamma in (0.03, 0.1, 0.3, 1.0, 3.0, 10.0):
            cfg = KFACConfig(use_rescale=rescale, use_momentum=False,
                             lambda_init=1.0, fixed_lr=1.0)
            opt = KFAC(mlp, cfg, family="bernoulli")
            rng = jax.random.PRNGKey(0)
            state = state0.replace(gamma=jnp.float32(gamma))
            state, grads, metr = opt.stats_grads(state, params, batch, rng)
            state = opt.refresh_inverses(state)
            new_params, state, um = opt.apply_update(state, params, grads,
                                                     batch, rng)
            (l_new, _), _ = mlp.loss(new_params, None, batch, rng, "plain")
            improve = float(metr["loss"] - l_new)
            rows.append((f"damping_gamma{gamma}_rescale{int(rescale)}",
                         0.0, improve))
            if improve > best[1]:
                best = (gamma, improve)
        rows.append((f"damping_best_rescale{int(rescale)}", best[0] or 0.0,
                     best[1]))
    return rows


if __name__ == "__main__":
    for name, us, val in run():
        print(f"{name},{us:.0f},{val:.5f}")
