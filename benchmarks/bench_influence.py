"""Curvature-service benchmark: iHVP throughput + uncertainty-decode cost.

Measures the two product surfaces of ``repro.curvature``:

* **iHVP throughput** — batched EKFAC inverse-Hessian-vector products over
  a bundle snapshotted from a partially-trained autoencoder
  (``ihvp_mlp_xla``), an XLA-vs-Pallas pair on a tileable 128x128 dense
  block (``ihvp_block128_xla`` / ``ihvp_block128_pallas``: the batched
  ``rotate_rescale`` route vs its einsum fallback — the MLP's
  homogeneous-coordinate a_dims never satisfy ``tile_ok`` so the realistic
  row is XLA-only), plus one full influence attribution query
  (``influence_query_topk``: per-example grads -> iHVP -> N dot
  products -> top-k).
* **uncertainty-decode overhead** — the smollm reduced engine serving the
  identical greedy request stream with and without per-token Laplace
  variance (``uncertainty_decode_overhead``): ``derived`` is the
  with/without wall-clock ratio and the row carries ``plain_us`` and
  ``overhead_frac`` meta.  The variance head is one extra
  ``(B, d) @ (d, V)`` matmul per step, so the ratio should sit near 1.

Rows land in ``BENCH_influence.json`` (benchlib schema; ``derived`` =
vectors/s for iHVP rows, examples/s for the influence row, overhead ratio
for the uncertainty row).

CLI:  --quick   smaller batches / fewer repeats (CI bench-smoke)
      --check   validate schema + uncertainty rows carry finite overhead
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.configs.base import KFACConfig
from repro.core.blocks import build_blocks
from repro.curvature import (CurvatureBundle, InfluenceEngine, LaplaceHead,
                             per_example_grads, snapshot_bundle)
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.lm import LM
from repro.models.mlp import MLP
from repro.optimizers import kfac
from repro.serving.server import Engine, Request

DIMS = [64, 48, 24, 12, 24, 48, 64]


def _trained_bundle(steps=10):
    """Partially-trained DIMS autoencoder under EKFAC + its bundle."""
    mlp = MLP(DIMS, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(DIMS[0], 8, 1024, seed=7)
    batch = data.batch(0)
    opt = kfac(mlp, KFACConfig(inv_mode="eigen", lambda_init=3.0, t3=5),
               family="bernoulli")
    state = opt.init(params, batch)
    for step in range(steps):
        params, state, _ = opt.update(None, state, params, batch,
                                      jax.random.fold_in(
                                          jax.random.PRNGKey(1), step))
    return mlp, params, batch, snapshot_bundle(opt.engine, state)


def _time(fn, repeats):
    fn()                                    # compile/warm
    t0 = time.time()
    for _ in range(repeats):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / repeats


def _ihvp_rows(mlp, params, batch, bundle, quick):
    n_vec = 8 if quick else 16
    repeats = 3 if quick else 10
    grads = per_example_grads(mlp, params,
                              jax.tree.map(lambda x: x[:n_vec], batch))
    eng = InfluenceEngine(bundle)
    secs = _time(lambda: eng.ihvp_batched(grads), repeats)
    yield ("ihvp_mlp_xla", secs * 1e6, n_vec / secs, {"n_vectors": n_vec})
    # the MLP's homogeneous-coordinate dims (d_in+1) never tile, so the
    # backend pair runs on a tileable 128x128 dense block: the batched
    # rotate_rescale Pallas route vs its einsum fallback
    yield from _block128_rows(n_vec, repeats)
    query = jax.tree.map(lambda a: a[0], grads)

    def attribution():
        scores = eng.influence(query, grads)
        return eng.topk(scores, 5)

    secs = _time(attribution, repeats)
    yield ("influence_query_topk", secs * 1e6, n_vec / secs,
           {"n_examples": n_vec})


def _block128_rows(n_vec, repeats):
    from repro.core.tags import LayerMeta
    meta = LayerMeta(name="dense128", param_path=("w",), d_in=128,
                     d_out=128, kind="dense")
    vs = jax.random.normal(jax.random.PRNGKey(3), (n_vec, 128, 128))
    a = jax.random.normal(jax.random.PRNGKey(4), (512, 128)) / 16.0
    g = jax.random.normal(jax.random.PRNGKey(5), (512, 128)) / 16.0
    fac = {"a": a.T @ a + 0.1 * jnp.eye(128),
           "g": g.T @ g + 0.1 * jnp.eye(128)}
    for backend in ("xla", "pallas"):
        blk = build_blocks({"dense128": meta},
                           KFACConfig(kernel_backend=backend))["dense128"]
        eig = blk.eigen_state(fac, 0.1)
        fn = jax.jit(lambda v, b=blk, e=eig: b.ihvp_batched(e, v))
        secs = _time(lambda: fn(vs), repeats)
        yield (f"ihvp_block128_{backend}", secs * 1e6, n_vec / secs,
               {"n_vectors": n_vec})


def _identity_laplace(lm):
    """Zero-factor bundle: damp = gamma^2, finite positive variance —
    exercises the full uncertainty compute path without a training run."""
    name = "lm_head" if "lm_head" in lm.metas else "embed"
    meta = lm.metas[name]
    blk = build_blocks({name: meta}, KFACConfig())[name]
    eig = blk.eigen_state(blk.init_factors(), 1.0)
    return LaplaceHead(CurvatureBundle(
        step=0, lam=1.0, gamma=1.0, eta=0.0,
        metas={name: meta}, eigen={name: eig}))


def _serve_reqs(cfg, n, uncertainty):
    return [Request(uid=u, prompt=[(7 * u + j) % cfg.vocab_size
                                   for j in range(4 + u % 3)],
                    max_new=8, uncertainty=uncertainty) for u in range(n)]


def _uncertainty_row(quick):
    n_req = 4 if quick else 12
    cfg = get_reduced_config("smollm-135m")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    lap = _identity_laplace(lm)

    def drive(engine, unc):
        engine.run(_serve_reqs(cfg, n_req, unc), max_steps=10_000)  # warm
        engine.reset()
        t0 = time.time()
        rep = engine.run(_serve_reqs(cfg, n_req, unc), max_steps=10_000)
        return time.time() - t0, rep

    plain_s, _ = drive(Engine(lm, params, batch_slots=4, max_len=48), False)
    unc_s, rep = drive(Engine(lm, params, batch_slots=4, max_len=48,
                              laplace=lap), True)
    ratio = unc_s / plain_s
    return ("uncertainty_decode_overhead", unc_s * 1e6, ratio,
            {"plain_us": plain_s * 1e6, "overhead_frac": ratio - 1.0,
             "n_requests": n_req,
             "mean_token_variance": rep.mean_token_variance})


def run(quick: bool = False):
    """Yield benchlib rows; also used by benchmarks/run.py."""
    mlp, params, batch, bundle = _trained_bundle(steps=5 if quick else 10)
    yield from _ihvp_rows(mlp, params, batch, bundle, quick)
    yield _uncertainty_row(quick)


def _check(rows) -> None:
    from benchmarks import benchlib
    payload = benchlib.build_payload("influence", rows)
    benchlib.validate_rows(payload)
    names = {r[0] for r in rows}
    want = {"ihvp_mlp_xla", "ihvp_block128_xla", "ihvp_block128_pallas",
            "influence_query_topk", "uncertainty_decode_overhead"}
    if not want <= names:
        raise SystemExit(f"influence suite missing rows: {want - names}")
    print("[check] influence schema ok; "
          + ", ".join(f"{r[0]}={r[2]:.2f}" for r in rows))


def main() -> None:
    import argparse
    import os

    from benchmarks import benchlib

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()
    rows = list(run(quick=args.quick))
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.0f},{row[2]:.4f}")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    benchlib.emit_json(os.path.join(root, "BENCH_influence.json"),
                       "influence", rows)
    if args.check:
        _check(rows)


if __name__ == "__main__":
    main()
