"""Kernel microbenches (paper S8 cost model) through the *optimizer's own*
entry points: a DenseKronecker curvature block's fused factor accumulation,
two-sided preconditioning, fused update chain and EKFAC eigenbasis apply
(`rotate_rescale`), under both `kernel_backend` settings, plus the per-step
eigen diagonal re-estimation, the Newton–Schulz inverse and attention
reference rows.

On this CPU container the Pallas rows run in interpret mode — labelled
``pallas_interp`` (row name suffix and per-row ``backend`` field) so their
correctness-only wall-clock is never confused with a compiled number; on
TPU the same code paths compile and the suffix is plain ``pallas``.  What
matters is that these are the identical `factor_update`/`precondition`
routes `KFAC.stats_grads`/`KFAC.apply_update` execute with
`kernel_backend="pallas"` — the numbers describe the real optimizer step.

Every row carries per-row metadata (merged into its BENCH_kernels.json
entry): ``backend`` (xla | pallas | pallas_interp), ``tuned`` (the
autotuner's winning tile config when ``--autotune cache|force`` ran — the
real-backend tuning mode; None otherwise), and a ``flops``/``bytes`` cost
model that benchmarks/roofline.py turns into achieved-vs-peak fractions.

CLI:  --quick      small shapes + few iters (CI bench-smoke)
      --autotune M off | cache | force — tune on the live backend and
                   record the chosen config per row
      --check      schema-validate the emitted rows (benchlib.validate_rows)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import KFACConfig
from repro.core.blocks import build_blocks
from repro.core.tags import LayerMeta
from repro.kernels import ref


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def _label(backend: str) -> str:
    """Row label: pallas rows on a non-TPU host run the interpreter."""
    if backend == "pallas" and _interp():
        return "pallas_interp"
    return backend


def _tuned_cfg(kernel, shape, dtype, autotune):
    """The persisted autotuner winner for this row's problem (provenance
    for the BENCH json), or None when tuning was off / nothing won."""
    if autotune == "off":
        return None
    from repro.kernels.autotune import cached_entry
    entry = cached_entry(kernel, tuple(int(s) for s in shape), dtype,
                         interpret=_interp())
    return None if entry is None else entry.get("cfg")


def _meta(backend, flops, bytes_, tuned=None):
    return {"backend": _label(backend), "tuned": tuned,
            "flops": float(flops), "bytes": float(bytes_)}


def _dense_block(d_in, d_out, backend, inv_mode="blkdiag", autotune="off"):
    meta = LayerMeta("bench", ("w",), d_in=d_in, d_out=d_out, kind="dense")
    cfg = KFACConfig(kernel_backend=backend, inv_mode=inv_mode,
                     autotune=autotune)
    return build_blocks({"bench": meta}, cfg)["bench"]


def run(backends=("xla", "pallas"), iters=5, quick=False, autotune="off"):
    rows = []
    d, n = (256, 1024) if quick else (512, 4096)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    cot = jax.random.normal(jax.random.fold_in(key, 1), (n, d)) / n
    old = {"a": jnp.eye(d), "g": jnp.eye(d)}
    rec = {"a": x}
    v = jax.random.normal(jax.random.fold_in(key, 2), (d, d))
    a_inv = jnp.eye(d)
    g_inv = jnp.eye(d)
    mom = jnp.zeros((d, d), jnp.float32)
    eig = {"qa": jnp.eye(d), "qg": jnp.eye(d),
           "s": jnp.ones((d, d)), "damp": jnp.zeros((d, d))}

    for backend in backends:
        lab = _label(backend)
        blk = _dense_block(d, d, backend, autotune=autotune)
        # the S5 stats route KFAC.stats_grads runs: fused C <- eps C + a XtX
        f = jax.jit(lambda eps, b=blk: b.update_factors(
            old, rec, cot, {}, n, eps))
        us = _time(f, jnp.float32(0.95), iters=iters)
        flops = 2 * 2 * n * d * d              # both sides' rank updates
        bytes_ = 4 * (2 * n * d + 4 * d * d)   # x + cot in, old/new factors
        rows.append((f"factor_update_{d}_{lab}", us,
                     flops / (us * 1e-6) / 1e9,
                     _meta(backend, flops, bytes_,
                           _tuned_cfg("factor_update", (n, d), jnp.float32,
                                      autotune))))

        # the S4.2 apply route KFAC.apply_update runs: U = A^-1 V G^-1
        g = jax.jit(lambda vv, b=blk: b.precondition(
            {"a_inv": a_inv, "g_inv": g_inv}, vv))
        us = _time(g, v, iters=iters)
        flops = 2 * 2 * d ** 3
        bytes_ = 4 * 4 * d * d                 # a_inv, g_inv, v in; u out
        rows.append((f"precondition_{d}_{lab}", us,
                     flops / (us * 1e-6) / 1e9,
                     _meta(backend, flops, bytes_,
                           _tuned_cfg("precond", (d, d), jnp.float32,
                                      autotune))))

        # the fused fixed-lr chain (use_rescale=False):
        # D = alpha (A^-1 V G^-1) + mu M, plus ||D||^2 out of the same pass
        uc = jax.jit(lambda vv, b=blk: b.precond_momentum(
            {"a_inv": a_inv, "g_inv": g_inv}, vv, mom,
            jnp.float32(-0.05), jnp.float32(0.9))[0])
        us = _time(uc, v, iters=iters)
        flops = 2 * 2 * d ** 3 + 3 * d * d
        bytes_ = 4 * 5 * d * d                 # + momentum in
        rows.append((f"update_chain_{d}_{lab}", us,
                     flops / (us * 1e-6) / 1e9,
                     _meta(backend, flops, bytes_,
                           _tuned_cfg("update_chain", (d, d), jnp.float32,
                                      autotune))))

        # the eigen-mode apply route: U = Q_A[(Q_Aᵀ V Q_G)/(s+damp)]Q_Gᵀ
        eb = _dense_block(d, d, backend, inv_mode="eigen", autotune=autotune)
        r = jax.jit(lambda vv, b=eb: b.precondition_eigen(eig, vv))
        us = _time(r, v, iters=iters)
        flops = 4 * 2 * d ** 3
        bytes_ = 4 * 6 * d * d
        rows.append((f"rotate_rescale_{d}_{lab}", us,
                     flops / (us * 1e-6) / 1e9,
                     _meta(backend, flops, bytes_,
                           _tuned_cfg("rotate_rescale", (d, d), jnp.float32,
                                      autotune))))

    # the KFC conv stats route (1602.01407): fused im2col + patch-factor
    # accumulation straight from the raw input — the whisper conv1 shape
    # family, through ConvKronecker.update_factors on both backends
    from repro.models.conv import conv_meta
    cb, ct, cc = (2, 256, 64) if quick else (4, 1024, 128)
    cm = conv_meta("bench_conv", ("w",), spatial=(3,), stride=(1,),
                   c_in=cc, d_out=d, padding="SAME")
    cx = jax.random.normal(jax.random.fold_in(key, 3), (cb, ct, cc))
    ccot = jax.random.normal(jax.random.fold_in(key, 4), (cb, ct, d)) / (
        cb * ct)
    cold = {"a": jnp.eye(cm.a_dim), "g": jnp.eye(d)}
    cflop = 2 * cb * ct * (cm.a_dim ** 2 + d ** 2)
    cbytes = 4 * (cb * ct * (cc + d) + 2 * cm.a_dim ** 2 + 2 * d * d)
    for backend in backends:
        cfg = KFACConfig(kernel_backend=backend, autotune=autotune)
        cblk = build_blocks({"c": cm}, cfg)["c"]
        f = jax.jit(lambda eps, b=cblk: b.update_factors(
            cold, {"cx": cx}, ccot, {}, cb * ct, eps))
        us = _time(f, jnp.float32(0.95), iters=iters)
        rows.append((f"patch_factor_{cm.a_dim}_{_label(backend)}", us,
                     cflop / (us * 1e-6) / 1e9,
                     _meta(backend, cflop, cbytes,
                           _tuned_cfg("patch_factor", (ct, cc, 3, 1),
                                      jnp.float32, autotune))))

    # the per-step EKFAC diagonal re-estimation (rotate + square + blend);
    # an einsum path on every backend — one row, not one per backend
    eb = _dense_block(d, d, "xla", inv_mode="eigen")
    r2 = jax.jit(lambda vv, b=eb: b.rescale_step(eig, vv, jnp.float32(0.95)))
    us = _time(r2, v, iters=iters)
    flops = 2 * 2 * d ** 3
    rows.append((f"eigen_rescale_{d}", us, flops / (us * 1e-6) / 1e9,
                 _meta("xla", flops, 4 * 6 * d * d)))

    m = jax.random.normal(jax.random.PRNGKey(1), (d, d))
    m = m @ m.T / d + jnp.eye(d)
    ns_it = 12
    h = jax.jit(lambda m: ref.ns_inverse_ref(m, ns_it))
    us = _time(h, m, iters=iters)
    flops = ns_it * 2 * 2 * d ** 3
    rows.append((f"ns_inverse_{d}x{ns_it}", us, flops / (us * 1e-6) / 1e9,
                 _meta("xla", flops, 4 * 2 * d * d * ns_it)))

    b, hq, hkv, t, hd = 1, 8, 2, (256 if quick else 1024), 64
    q = jax.random.normal(jax.random.PRNGKey(3), (b, hq, t, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(4), (b, hkv, t, hd), jnp.float32)
    vv = jax.random.normal(jax.random.PRNGKey(5), (b, hkv, t, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    us = _time(fa, q, k, vv, iters=iters)
    flops = 4 * b * hq * t * t * hd
    rows.append((f"attention_ref_{t // 1024 or t}{'k' if t >= 1024 else ''}",
                 us, flops / (us * 1e-6) / 1e9,
                 _meta("xla", flops, 4 * (hq + 2 * hkv) * b * t * hd)))
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small shapes + 2 iters (CI bench-smoke)")
    ap.add_argument("--autotune", choices=("off", "cache", "force"),
                    default="off",
                    help="tune tile configs on the live backend and record "
                         "the winner per row")
    ap.add_argument("--check", action="store_true",
                    help="schema-validate the rows (benchlib.validate_rows)")
    args = ap.parse_args(argv)
    rows = run(iters=2 if args.quick else 5, quick=args.quick,
               autotune=args.autotune)
    for row in rows:
        tuned = row[3].get("tuned")
        print(f"{row[0]},{row[1]:.0f},{row[2]:.2f}"
              + (f",{tuned}" if tuned else ""))
    if args.check:
        try:
            from benchmarks import benchlib
        except ImportError:
            import benchlib
        benchlib.validate_rows(benchlib.build_payload("kernels", rows))
        print(f"schema OK ({len(rows)} rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
