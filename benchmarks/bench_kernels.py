"""Kernel microbenches (paper S8 cost model) through the *optimizer's own*
entry points: a DenseKronecker curvature block's fused factor accumulation,
two-sided preconditioning and EKFAC eigenbasis apply (`rotate_rescale`),
under both `kernel_backend` settings, plus the per-step eigen diagonal
re-estimation, the Newton–Schulz inverse and attention reference rows.

On this CPU container the Pallas rows run in interpret mode, so their
wall-clock is correctness-only; on TPU the same code paths compile.  What
matters is that these are the identical `factor_update`/`precondition`
routes `KFAC.stats_grads`/`KFAC.apply_update` execute with
`kernel_backend="pallas"` — the numbers describe the real optimizer step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import KFACConfig
from repro.core.blocks import build_blocks
from repro.core.tags import LayerMeta
from repro.kernels import ref


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _dense_block(d_in, d_out, backend, inv_mode="blkdiag"):
    meta = LayerMeta("bench", ("w",), d_in=d_in, d_out=d_out, kind="dense")
    cfg = KFACConfig(kernel_backend=backend, inv_mode=inv_mode)
    return build_blocks({"bench": meta}, cfg)["bench"]


def run(backends=("xla", "pallas"), iters=5):
    rows = []
    d, n = 512, 4096
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    cot = jax.random.normal(jax.random.fold_in(key, 1), (n, d)) / n
    old = {"a": jnp.eye(d), "g": jnp.eye(d)}
    rec = {"a": x}
    v = jax.random.normal(jax.random.fold_in(key, 2), (d, d))
    a_inv = jnp.eye(d)
    g_inv = jnp.eye(d)
    eig = {"qa": jnp.eye(d), "qg": jnp.eye(d),
           "s": jnp.ones((d, d)), "damp": jnp.zeros((d, d))}

    for backend in backends:
        blk = _dense_block(d, d, backend)
        # the S5 stats route KFAC.stats_grads runs: fused C <- eps C + a XtX
        f = jax.jit(lambda eps, b=blk: b.update_factors(
            old, rec, cot, {}, n, eps))
        us = _time(f, jnp.float32(0.95), iters=iters)
        rows.append((f"factor_update_{d}_{backend}", us,
                     2 * 2 * n * d * d / (us * 1e-6) / 1e9))

        # the S4.2 apply route KFAC.apply_update runs: U = A^-1 V G^-1
        g = jax.jit(lambda vv, b=blk: b.precondition(
            {"a_inv": a_inv, "g_inv": g_inv}, vv))
        us = _time(g, v, iters=iters)
        rows.append((f"precondition_{d}_{backend}", us,
                     2 * 2 * d ** 3 / (us * 1e-6) / 1e9))

        # the eigen-mode apply route: U = Q_A[(Q_Aᵀ V Q_G)/(s+damp)]Q_Gᵀ
        eb = _dense_block(d, d, backend, inv_mode="eigen")
        r = jax.jit(lambda vv, b=eb: b.precondition_eigen(eig, vv))
        us = _time(r, v, iters=iters)
        rows.append((f"rotate_rescale_{d}_{backend}", us,
                     4 * 2 * d ** 3 / (us * 1e-6) / 1e9))

    # the KFC conv stats route (1602.01407): fused im2col + patch-factor
    # accumulation straight from the raw input — the whisper conv1 shape
    # family, through ConvKronecker.update_factors on both backends
    from repro.models.conv import conv_meta
    cb, ct, cc = 4, 1024, 128
    cm = conv_meta("bench_conv", ("w",), spatial=(3,), stride=(1,),
                   c_in=cc, d_out=d, padding="SAME")
    cx = jax.random.normal(jax.random.fold_in(key, 3), (cb, ct, cc))
    ccot = jax.random.normal(jax.random.fold_in(key, 4), (cb, ct, d)) / (
        cb * ct)
    cold = {"a": jnp.eye(cm.a_dim), "g": jnp.eye(d)}
    cflop = 2 * cb * ct * (cm.a_dim ** 2 + d ** 2)
    for backend in backends:
        cfg = KFACConfig(kernel_backend=backend)
        cblk = build_blocks({"c": cm}, cfg)["c"]
        f = jax.jit(lambda eps, b=cblk: b.update_factors(
            cold, {"cx": cx}, ccot, {}, cb * ct, eps))
        us = _time(f, jnp.float32(0.95), iters=iters)
        rows.append((f"patch_factor_{cm.a_dim}_{backend}", us,
                     cflop / (us * 1e-6) / 1e9))

    # the per-step EKFAC diagonal re-estimation (rotate + square + blend);
    # an einsum path on every backend — one row, not one per backend
    eb = _dense_block(d, d, "xla", inv_mode="eigen")
    r2 = jax.jit(lambda vv, b=eb: b.rescale_step(eig, vv, jnp.float32(0.95)))
    us = _time(r2, v, iters=iters)
    rows.append((f"eigen_rescale_{d}", us,
                 2 * 2 * d ** 3 / (us * 1e-6) / 1e9))

    m = jax.random.normal(jax.random.PRNGKey(1), (d, d))
    m = m @ m.T / d + jnp.eye(d)
    h = jax.jit(lambda m: ref.ns_inverse_ref(m, 12))
    us = _time(h, m, iters=iters)
    rows.append(("ns_inverse_512x12", us,
                 12 * 2 * 2 * d ** 3 / (us * 1e-6) / 1e9))

    b, hq, hkv, t, hd = 1, 8, 2, 1024, 64
    q = jax.random.normal(jax.random.PRNGKey(3), (b, hq, t, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(4), (b, hkv, t, hd), jnp.float32)
    vv = jax.random.normal(jax.random.PRNGKey(5), (b, hkv, t, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    us = _time(fa, q, k, vv, iters=iters)
    rows.append(("attention_ref_1k", us,
                 4 * b * hq * t * t * hd / (us * 1e-6) / 1e9))
    return rows


if __name__ == "__main__":
    for name, us, gf in run():
        print(f"{name},{us:.0f},{gf:.2f}")
