"""Kernel microbenches (paper S8 cost model): wall-clock of the pure-jnp
paths (what this CPU container executes) + analytic flops.  On TPU the
Pallas kernels replace these; interpret-mode timings are correctness-only."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rows = []
    d, n = 512, 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    c = jnp.zeros((d, d))
    f = jax.jit(lambda x, c: ref.factor_update_ref(x, c, alpha=0.05,
                                                   beta=0.95))
    us = _time(f, x, c)
    rows.append(("factor_update_512", us, 2 * n * d * d / (us * 1e-6) / 1e9))

    m = jax.random.normal(jax.random.PRNGKey(1), (d, d))
    m = m @ m.T / d + jnp.eye(d)
    g = jax.jit(lambda m: ref.ns_inverse_ref(m, 12))
    us = _time(g, m)
    rows.append(("ns_inverse_512x12", us, 12 * 2 * 2 * d ** 3 / (us * 1e-6) / 1e9))

    a_inv = jnp.eye(d)
    g_inv = jnp.eye(d)
    v = jax.random.normal(jax.random.PRNGKey(2), (d, d))
    h = jax.jit(ref.precondition_ref)
    us = _time(h, a_inv, v, g_inv)
    rows.append(("precondition_512", us, 2 * 2 * d ** 3 / (us * 1e-6) / 1e9))

    b, hq, hkv, t, hd = 1, 8, 2, 1024, 64
    q = jax.random.normal(jax.random.PRNGKey(3), (b, hq, t, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(4), (b, hkv, t, hd), jnp.float32)
    vv = jax.random.normal(jax.random.PRNGKey(5), (b, hkv, t, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    us = _time(fa, q, k, vv)
    rows.append(("attention_ref_1k", us,
                 4 * b * hq * t * t * hd / (us * 1e-6) / 1e9))
    return rows


if __name__ == "__main__":
    for name, us, gf in run():
        print(f"{name},{us:.0f},{gf:.2f}")
