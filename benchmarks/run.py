"""Benchmark harness entry — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Heavy multi-pod numbers come from
the dry-run artifacts (see repro.launch.dryrun + benchmarks.roofline).
"""
from __future__ import annotations

import traceback


def main() -> None:
    suites = []
    from benchmarks import (bench_optimizer_race, bench_damping,
                            bench_fisher_quality, bench_batch_scaling,
                            bench_kernels, roofline)
    suites = [
        ("optimizer_race", bench_optimizer_race.run),   # Fig. 10/11
        ("damping", bench_damping.run),                 # Fig. 7
        ("fisher_quality", bench_fisher_quality.run),   # Fig. 2/3/5/6
        ("batch_scaling", bench_batch_scaling.run),     # Fig. 9
        ("kernels", bench_kernels.run),                 # S8 cost model
        ("roofline", roofline.run),                     # dry-run derived
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.0f},{row[2]:.4f}", flush=True)
        except Exception:  # noqa: BLE001
            print(f"{name},0,ERROR")
            traceback.print_exc()


if __name__ == '__main__':
    main()
