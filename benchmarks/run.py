"""Benchmark harness entry — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; the kernel and optimizer-race
suites additionally land as machine-readable ``BENCH_kernels.json`` /
``BENCH_optimizer.json`` at the repo root (schema in benchlib's docstring),
so the bench trajectory is diffable across commits.  Heavy multi-pod numbers
come from the dry-run artifacts (see repro.launch.dryrun +
benchmarks.roofline).
"""
from __future__ import annotations

import os
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# suite name -> BENCH_*.json filename for the machine-readable trajectory
_JSON_SUITES = {"kernels": "BENCH_kernels.json",
                "optimizer_race": "BENCH_optimizer.json",
                "serving": "BENCH_serving.json",
                "influence": "BENCH_influence.json"}

# per-suite extra row fields (see benchlib docstring for the schema).  The
# obs_overhead row's derived is an overhead fraction, not a loss — it must
# not be relabelled final_loss.
_JSON_EXTRAS = {
    "optimizer_race": lambda n, us, dv: (
        {"wall_s_per_step": us * 1e-6} if n == "obs_overhead"
        else {"wall_s_per_step": us * 1e-6, "final_loss": dv}),
}


def main() -> None:
    suites = []
    from benchmarks import (bench_optimizer_race, bench_damping,
                            bench_fisher_quality, bench_batch_scaling,
                            bench_influence, bench_kernels, bench_serving,
                            benchlib, roofline)
    suites = [
        ("optimizer_race", bench_optimizer_race.run),   # Fig. 10/11
        ("damping", bench_damping.run),                 # Fig. 7
        ("fisher_quality", bench_fisher_quality.run),   # Fig. 2/3/5/6
        ("batch_scaling", bench_batch_scaling.run),     # Fig. 9
        ("kernels", bench_kernels.run),                 # S8 cost model
        ("serving", bench_serving.run),                 # continuous batching
        ("influence", bench_influence.run),             # curvature service
        ("roofline", roofline.run),                     # dry-run derived
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites:
        try:
            rows = list(fn())
            for row in rows:
                print(f"{row[0]},{row[1]:.0f},{row[2]:.4f}", flush=True)
            if name in _JSON_SUITES:
                benchlib.emit_json(os.path.join(_ROOT, _JSON_SUITES[name]),
                                   name, rows, extras=_JSON_EXTRAS.get(name))
        except Exception:  # noqa: BLE001
            print(f"{name},0,ERROR")
            traceback.print_exc()
            failed.append(name)
    # a broken tracked suite must fail the harness (and its CI job) rather
    # than ship a stale/absent BENCH_*.json alongside a green exit code
    tracked = [n for n in failed if n in _JSON_SUITES]
    if tracked:
        raise SystemExit(f"tracked bench suite(s) failed: {tracked}")


if __name__ == '__main__':
    main()
