"""Refresh-service bench: serial vs sharded vs overlap inverse refresh.

Times the T3 d³ refresh — the cost the paper amortizes temporally (S8)
and ``repro.distributed`` spreads spatially — on a forced 8-device CPU
mesh, across the registered bench configs:

  * ``serial``   — every device recomputes every block (today's spike);
  * ``sharded``  — block-parallel shard_map refresh, ~Sum(d^3)/P critical
                   path (same bits, less wall time);
  * ``overlap``  — dispatch latency of the async double-buffered mode:
                   what the *training step* actually waits for when the
                   refresh runs concurrently.

This module must own the process (it forces
``--xla_force_host_platform_device_count=8`` before jax initializes), so
it is NOT part of ``benchmarks/run.py``'s in-process suite — run it
directly::

    PYTHONPATH=src:. python benchmarks/bench_refresh.py

Output: ``name,us_per_call,speedup_vs_serial`` CSV rows per config.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import time  # noqa: E402

import jax  # noqa: E402

from repro import optimizers  # noqa: E402
from repro.configs.base import KFACConfig  # noqa: E402
from repro.data.pipeline import (SyntheticAutoencoderData,  # noqa: E402
                                 SyntheticImageData)
from repro.distributed.refresh import build_sharded_refresh  # noqa: E402
from repro.models.mlp import MLP  # noqa: E402

REPS = 5


def _autoencoder(dims, n=64):
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(dims[0], 4, n, seed=7)
    return mlp, params, data, "bernoulli"


def _conv(n=64):
    from repro.configs.conv_classifier import reduced
    from repro.models.convnet import ConvNet
    cfg = reduced()
    net = ConvNet(cfg)
    params = net.init_params(jax.random.PRNGKey(0))
    data = SyntheticImageData(cfg.image_size, cfg.channels, cfg.n_classes,
                              n, seed=7)
    return net, params, data, "categorical"


# registered bench configs: name -> problem factory.  The deep_mlp row is
# the representative production shape (eight 512-wide factor inversions per
# side) where the d³ term dominates scheduling overhead; the tiny
# autoencoder/conv rows sit below the sharding break-even on purpose —
# they document the fixed shard_map + collective cost you pay to spread
# work that a single device finishes in ~1ms anyway.
CONFIGS = {
    "autoencoder": lambda: _autoencoder([64, 32, 16, 8, 16, 32, 64]),
    "deep_mlp_512": lambda: _autoencoder([512] * 9),
    "conv_classifier": _conv,
}


def _time(fn, reps=REPS):
    fn()                                    # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_config(name, factory, inv_mode="blkdiag"):
    # inverse_method="ns" (the production default): Newton–Schulz is
    # matmul-only, so the per-block work parallelizes cleanly across the
    # fake CPU devices.  (eigh on CPU inside an SPMD executable cannot hit
    # the LAPACK custom call and falls back to the slow pure-HLO path — a
    # CPU-only artifact; on TPU eigh is the HLO implementation either way.)
    model, params, data, family = factory()
    kcfg = KFACConfig(inv_mode=inv_mode, inverse_method="ns",
                      lambda_init=3.0, t3=5, eta=1e-5)
    opt = optimizers.kfac(model, kcfg, family=family)
    eng = opt.engine
    state = opt.init(params, data.batch(0))
    state, grads, _ = jax.jit(eng.stats_grads)(
        state, params, data.batch(0), jax.random.PRNGKey(1))

    serial = jax.jit(lambda s: eng.refresh_inverses(s, hot=True))
    sharded = build_sharded_refresh(eng)

    t_serial = _time(lambda: jax.block_until_ready(serial(state)))
    t_sharded = _time(lambda: jax.block_until_ready(
        sharded(state.factors, state.gamma, state.inv)))
    # overlap: the trainer-visible stall is the async dispatch, not the
    # refresh itself — time the call without blocking on the result
    t_dispatch = _time(
        lambda: sharded(state.factors, state.gamma, state.inv))

    rows = [
        (f"refresh_{name}_serial", t_serial * 1e6, 1.0),
        (f"refresh_{name}_sharded", t_sharded * 1e6,
         t_serial / max(t_sharded, 1e-12)),
        (f"refresh_{name}_overlap_dispatch", t_dispatch * 1e6,
         t_serial / max(t_dispatch, 1e-12)),
    ]
    return rows, t_serial, t_sharded


def run():
    all_rows = []
    for name, factory in CONFIGS.items():
        rows, _, _ = bench_config(name, factory)
        all_rows.extend(rows)
    return all_rows


def main():
    print(f"# devices: {len(jax.devices())}")
    print("name,us_per_call,speedup_vs_serial")
    slower = []
    for name, factory in CONFIGS.items():
        rows, t_serial, t_sharded = bench_config(name, factory)
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]:.4f}", flush=True)
        if t_sharded >= t_serial:
            slower.append(name)
    if slower:
        print(f"# WARNING: sharded refresh not faster for: {slower}")


if __name__ == "__main__":
    main()
