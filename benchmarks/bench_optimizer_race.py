"""Paper Fig. 10/11 analogue: K-FAC variants vs tuned SGD+momentum on a deep
autoencoder — per-iteration progress is the paper's headline claim."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import KFACConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP

DIMS = [64, 48, 24, 12, 24, 48, 64]


def make_problem(n=1024, seed=7):
    mlp = MLP(DIMS, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(DIMS[0], 8, n, seed=seed)
    return mlp, params, data.batch(0)


def run_kfac(steps=30, inv_mode="blkdiag", momentum=True, rescale=True,
             lambda_init=3.0):
    mlp, params, batch = make_problem()
    cfg = KFACConfig(inv_mode=inv_mode, use_momentum=momentum,
                     use_rescale=rescale, lambda_init=lambda_init, t3=5,
                     fixed_lr=0.02, eta=1e-5)
    opt = KFAC(mlp, cfg, family="bernoulli")
    state = opt.init(params, batch)
    stats = jax.jit(opt.stats_grads)
    refresh = jax.jit(opt.refresh_inverses)
    rescale = jax.jit(opt.rescale_step)
    update = jax.jit(lambda s, p, g, b, r: opt.apply_update(s, p, g, b, r))
    lam = jax.jit(opt.lambda_step)
    losses, t0 = [], time.time()
    for step in range(steps):
        rng = jax.random.PRNGKey(1000 + step)
        state, grads, metr = stats(state, params, batch, rng)
        if step % cfg.t3 == 0 or step < 3:
            state = refresh(state)
        if inv_mode == "eigen":
            state = rescale(state, grads)
        params, state, _ = update(state, params, grads, batch, rng)
        if (step + 1) % cfg.t1 == 0:
            state, _ = lam(state, params, batch, rng)
        losses.append(float(metr["loss"]))
    return losses, time.time() - t0


def run_conv_kfac(steps=30, inv_mode="blkdiag"):
    """KFC conv classifier (1602.01407): K-FAC on the reduced ConvNet —
    tracks the ConvKronecker path (patch stats + conv preconditioning)."""
    from repro.configs.conv_classifier import reduced
    from repro.data.pipeline import SyntheticImageData
    from repro.models.convnet import ConvNet

    cfg = reduced()
    net = ConvNet(cfg)
    params = net.init_params(jax.random.PRNGKey(0))
    data = SyntheticImageData(cfg.image_size, cfg.channels, cfg.n_classes,
                              512, seed=7)
    batch = data.batch(0)
    kcfg = KFACConfig(inv_mode=inv_mode, lambda_init=3.0, t3=5, eta=1e-5)
    opt = KFAC(net, kcfg, family="categorical")
    state = opt.init(params, batch)
    stats = jax.jit(opt.stats_grads)
    refresh = jax.jit(opt.refresh_inverses)
    rescale = jax.jit(opt.rescale_step)
    update = jax.jit(lambda s, p, g, b, r: opt.apply_update(s, p, g, b, r))
    losses, t0 = [], time.time()
    for step in range(steps):
        rng = jax.random.PRNGKey(1000 + step)
        state, grads, metr = stats(state, params, batch, rng)
        if step % kcfg.t3 == 0 or step < 3:
            state = refresh(state)
        if inv_mode == "eigen":
            state = rescale(state, grads)
        params, state, _ = update(state, params, grads, batch, rng)
        losses.append(float(metr["loss"]))
    return losses, time.time() - t0


def run_sgd(steps=30, lr=0.1, mom=0.9):
    mlp, params, batch = make_problem()

    def loss_fn(p):
        (lt, _), _ = mlp.loss(p, None, batch, jax.random.PRNGKey(0), "plain")
        return lt

    gfn = jax.jit(jax.value_and_grad(loss_fn))
    vel = jax.tree.map(jnp.zeros_like, params)
    losses, t0 = [], time.time()
    for _ in range(steps):
        l, g = gfn(params)
        vel = jax.tree.map(lambda v, gg: mom * v - lr * gg, vel, g)
        params = jax.tree.map(lambda p, v: p + v, params, vel)
        losses.append(float(l))
    return losses, time.time() - t0


def run(steps=30):
    rows = []
    for lr in (0.03, 0.1, 0.3):           # "tuned" = best of a small grid
        losses, secs = run_sgd(steps, lr=lr)
        rows.append((f"sgd_momentum_lr{lr}", secs / steps * 1e6, losses[-1]))
    kf, secs = run_kfac(steps, "blkdiag")
    rows.append(("kfac_blkdiag", secs / steps * 1e6, kf[-1]))
    kf, secs = run_kfac(steps, "tridiag")
    rows.append(("kfac_tridiag", secs / steps * 1e6, kf[-1]))
    kf, secs = run_kfac(steps, "eigen")
    rows.append(("kfac_eigen", secs / steps * 1e6, kf[-1]))
    kf, secs = run_kfac(steps, "blkdiag", momentum=False)
    rows.append(("kfac_no_momentum", secs / steps * 1e6, kf[-1]))
    kf, secs = run_conv_kfac(steps, "blkdiag")
    rows.append(("kfac_conv_classifier", secs / steps * 1e6, kf[-1]))
    kf, secs = run_conv_kfac(steps, "eigen")
    rows.append(("kfac_conv_classifier_eigen", secs / steps * 1e6, kf[-1]))
    return rows


if __name__ == "__main__":
    for name, us, loss in run():
        print(f"{name},{us:.0f},{loss:.4f}")
