"""Paper Fig. 10/11 analogue: K-FAC variants vs first-order baselines on a
deep autoencoder — per-iteration progress is the paper's headline claim.

Every optimizer here — K-FAC (all inv_modes), SGD+momentum, Adam — is an
``repro.core.transform.Optimizer`` raced through the *identical*
``Trainer.fit`` loop: no optimizer-specific branches anywhere in the race.
The sgd/adam rows give the perf trajectory its first-order reference line
(wall_s_per_step + final loss land in ``BENCH_optimizer.json``).
"""
from __future__ import annotations

import time

from repro import optimizers
from repro.configs.base import KFACConfig, TrainConfig
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP

import jax

DIMS = [64, 48, 24, 12, 24, 48, 64]


def make_problem(n=1024, seed=7):
    mlp = MLP(DIMS, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(DIMS[0], 8, n, seed=seed)
    return mlp, params, data


def race(model, params, data, opt, steps, obs=None):
    """One optimizer through the shared trainer loop; returns
    (per-step losses, wall seconds)."""
    from repro.training.trainer import Trainer
    tr = Trainer(model, opt, TrainConfig(steps=steps, seed=0,
                                         log_every=10_000_000), obs=obs)
    t0 = time.time()
    out = tr.fit(params, data, steps=steps, log=lambda *_: None)
    return [h["loss"] for h in out["history"]], time.time() - t0


def run_kfac(steps=30, inv_mode="blkdiag", momentum=True, rescale=True,
             lambda_init=3.0, refresh_mode="serial", kl_clip=0.0):
    mlp, params, data = make_problem()
    cfg = KFACConfig(inv_mode=inv_mode, use_momentum=momentum,
                     use_rescale=rescale, lambda_init=lambda_init, t3=5,
                     fixed_lr=0.02, eta=1e-5, refresh_mode=refresh_mode,
                     kl_clip=kl_clip)
    opt = optimizers.kfac(mlp, cfg, family="bernoulli")
    return race(mlp, params, data, opt, steps)


def run_conv_kfac(steps=30, inv_mode="blkdiag"):
    """KFC conv classifier (1602.01407): K-FAC on the reduced ConvNet —
    tracks the ConvKronecker path (patch stats + conv preconditioning)."""
    from repro.configs.conv_classifier import reduced
    from repro.data.pipeline import SyntheticImageData
    from repro.models.convnet import ConvNet

    cfg = reduced()
    net = ConvNet(cfg)
    params = net.init_params(jax.random.PRNGKey(0))
    data = SyntheticImageData(cfg.image_size, cfg.channels, cfg.n_classes,
                              512, seed=7)
    kcfg = KFACConfig(inv_mode=inv_mode, lambda_init=3.0, t3=5, eta=1e-5)
    opt = optimizers.kfac(net, kcfg, family="categorical")
    return race(net, params, data, opt, steps)


def run_obs_overhead(steps=30):
    """The telemetry overhead contract (docs/observability.md): the same
    blkdiag K-FAC race, fully instrumented (stage spans + per-step events
    to a JSONL sink) vs disabled.  Each side is warmed first (shared jit
    cache inside one optimizer) and takes the best of two timed runs, so
    the ratio measures instrumentation, not compile noise.  Returns
    (disabled_s, enabled_s, stage-mean dict from the registry)."""
    import os
    import tempfile

    from repro.obs import Obs, ObsConfig

    def timed(obs):
        mlp, params, data = make_problem()
        cfg = KFACConfig(inv_mode="blkdiag", lambda_init=3.0, t3=5,
                         eta=1e-5)
        opt = optimizers.kfac(mlp, cfg, family="bernoulli", obs=obs)
        race(mlp, params, data, opt, steps, obs=obs)      # warmup/compile
        return min(race(mlp, params, data, opt, steps, obs=obs)[1]
                   for _ in range(2))

    off_s = timed(None)
    path = os.path.join(tempfile.mkdtemp(prefix="repro_obs_"),
                        "race_obs.jsonl")
    obs = Obs(ObsConfig(enabled=True, jsonl_path=path))
    on_s = timed(obs)
    obs.close()
    stages = {k: v["mean"]
              for k, v in obs.registry.snapshot()["histogram"].items()
              if k.startswith("span_s")}
    return off_s, on_s, stages


def run_sgd(steps=30, lr=0.1, mom=0.9):
    mlp, params, data = make_problem()
    opt = optimizers.sgd_momentum(mlp, lr=lr, momentum=mom)
    return race(mlp, params, data, opt, steps)


def run_adam(steps=30, lr=1e-2):
    mlp, params, data = make_problem()
    opt = optimizers.adam(mlp, lr=lr)
    return race(mlp, params, data, opt, steps)


def run(steps=30):
    rows = []
    for lr in (0.03, 0.1, 0.3):           # "tuned" = best of a small grid
        losses, secs = run_sgd(steps, lr=lr)
        rows.append((f"sgd_momentum_lr{lr}", secs / steps * 1e6, losses[-1]))
    # the swappable first-order baselines at their default settings — the
    # BENCH_optimizer.json reference line for the K-FAC rows below
    losses, secs = run_sgd(steps)
    rows.append(("sgd_momentum", secs / steps * 1e6, losses[-1]))
    losses, secs = run_adam(steps)
    rows.append(("adam", secs / steps * 1e6, losses[-1]))
    kf, secs = run_kfac(steps, "blkdiag")
    rows.append(("kfac_blkdiag", secs / steps * 1e6, kf[-1]))
    kf, secs = run_kfac(steps, "tridiag")
    rows.append(("kfac_tridiag", secs / steps * 1e6, kf[-1]))
    kf, secs = run_kfac(steps, "eigen")
    rows.append(("kfac_eigen", secs / steps * 1e6, kf[-1]))
    kf, secs = run_kfac(steps, "blkdiag", momentum=False)
    rows.append(("kfac_no_momentum", secs / steps * 1e6, kf[-1]))
    # KL-clipped fixed-lr chain (transform.with_kl_clip / KFACConfig.kl_clip):
    # the production norm-constraint knob, raced on the fused update path
    kf, secs = run_kfac(steps, "blkdiag", rescale=False, kl_clip=1e-3)
    rows.append(("kfac_kl_clip", secs / steps * 1e6, kf[-1]))
    # distributed refresh service (repro.distributed): same optimizer, the
    # T3 inverse refresh executed block-parallel / async double-buffered.
    # On this 1-device CPU harness these rows track the *scheduling
    # overhead* (parallel speedups need a real mesh — see bench_refresh.py)
    for rmode in ("staggered", "sharded", "overlap"):
        kf, secs = run_kfac(steps, "blkdiag", refresh_mode=rmode)
        rows.append((f"kfac_refresh_{rmode}", secs / steps * 1e6, kf[-1]))
    kf, secs = run_conv_kfac(steps, "blkdiag")
    rows.append(("kfac_conv_classifier", secs / steps * 1e6, kf[-1]))
    kf, secs = run_conv_kfac(steps, "eigen")
    rows.append(("kfac_conv_classifier_eigen", secs / steps * 1e6, kf[-1]))
    # telemetry overhead: same blkdiag race, obs fully enabled vs disabled.
    # derived IS the overhead fraction (the row's claim, like the influence
    # suite's uncertainty row); the contract is < 5% (docs/observability.md)
    off_s, on_s, stages = run_obs_overhead(steps)
    rows.append(("obs_overhead", on_s / steps * 1e6, (on_s - off_s) / off_s,
                 {"disabled_us_per_step": off_s / steps * 1e6,
                  "enabled_us_per_step": on_s / steps * 1e6,
                  "overhead_frac": (on_s - off_s) / off_s,
                  "stage_mean_s": stages}))
    return rows


if __name__ == "__main__":
    for name, us, loss in run():
        print(f"{name},{us:.0f},{loss:.4f}")
