"""Shared helpers for the benchmark harnesses."""
from __future__ import annotations

import jax

from repro.configs.base import KFACConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP

DIMS = [64, 48, 24, 12, 24, 48, 64]


def partially_train(steps=12, dims=None):
    """A partially-trained autoencoder + live K-FAC state (the paper's Fig. 7
    setup uses the iteration-500 network; we use a miniature analogue)."""
    dims = dims or DIMS
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(dims[0], 8, 1024, seed=7)
    batch = data.batch(0)
    cfg = KFACConfig(lambda_init=3.0, t3=5)
    opt = KFAC(mlp, cfg, family="bernoulli")
    state = opt.init(params, batch)
    for step in range(steps):
        rng = jax.random.PRNGKey(1000 + step)
        state, grads, _ = opt.stats_grads(state, params, batch, rng)
        if step % cfg.t3 == 0 or step < 3:
            state = opt.refresh_inverses(state)
        params, state, _ = opt.apply_update(state, params, grads, batch, rng)
    return mlp, params, batch, state
