"""Shared helpers for the benchmark harnesses.

Machine-readable output — the ``BENCH_*.json`` schema
-----------------------------------------------------
``benchmarks/run.py`` writes one JSON file per tracked suite at the repo
root (``BENCH_kernels.json``, ``BENCH_optimizer.json``) via
:func:`emit_json`, so the bench trajectory can be diffed across commits and
uploaded as a CI artifact.  Each file is::

    {
      "suite":   "<suite name>",            # e.g. "kernels"
      "backend": "<jax.default_backend()>", # cpu | tpu | ...
      "rows": [
        {"name": "<row name>",              # e.g. "rotate_rescale_512_pallas"
         "us_per_call": <float>,            # mean wall-clock per call, µs
         "derived": <float>,                # row-specific: GFLOP/s for
         ...},                              # kernel rows, final loss for
      ]                                     # optimizer-race rows
    }

Suites may add per-row fields via ``emit_json``'s ``extras`` hook; the
optimizer-race suite adds ``wall_s_per_step`` (seconds, = us_per_call/1e6)
and ``final_loss`` (= derived) so the K-FAC rows carry an explicit
first-order reference line (``sgd_momentum`` / ``adam`` rows).

Row names are stable identifiers: kernel rows are
``<entry_point>_<dim>[_<kernel_backend>]``; optimizer rows are
``<optimizer>_<variant>``.  On CPU the Pallas rows run in interpret mode, so
their wall-clock is correctness-only — compare like backends across commits,
not backends against each other.
"""
from __future__ import annotations

import json
import math

import jax

from repro.configs.base import KFACConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP

DIMS = [64, 48, 24, 12, 24, 48, 64]


def build_payload(suite: str, rows, extras=None) -> dict:
    """The BENCH_*.json payload for one suite's rows.

    Rows are ``(name, us, derived)`` or ``(name, us, derived, meta)`` tuples;
    ``meta`` is a per-row dict merged into the row (the kernels suite carries
    ``backend`` / ``tuned`` / ``flops`` / ``bytes`` provenance this way).
    ``extras``: optional ``(name, us, derived) -> dict`` adding suite-
    specific per-row fields (see the schema note in the module docstring).
    """
    out_rows = []
    for row in rows:
        n, us, dv = row[0], row[1], row[2]
        meta = row[3] if len(row) > 3 and row[3] else {}
        out_rows.append({"name": n, "us_per_call": float(us),
                         "derived": float(dv), **meta,
                         **(extras(n, us, dv) if extras else {})})
    return {"suite": suite, "backend": jax.default_backend(),
            "rows": out_rows}


def validate_rows(payload: dict) -> dict:
    """Schema check for a BENCH_*.json payload (CI bench-smoke): raises
    ValueError on any malformed row, returns the payload unchanged."""
    if not isinstance(payload, dict):
        raise ValueError(f"payload is {type(payload).__name__}, not dict")
    for field in ("suite", "backend", "rows"):
        if field not in payload:
            raise ValueError(f"payload missing {field!r}")
    if not isinstance(payload["rows"], list) or not payload["rows"]:
        raise ValueError("payload rows must be a non-empty list")
    for i, row in enumerate(payload["rows"]):
        if not isinstance(row, dict):
            raise ValueError(f"row {i} is not a dict")
        if not isinstance(row.get("name"), str) or not row["name"]:
            raise ValueError(f"row {i} has no name")
        for field in ("us_per_call", "derived"):
            v = row.get(field)
            if not isinstance(v, (int, float)) or not math.isfinite(v):
                raise ValueError(
                    f"row {row['name']!r}: {field} = {v!r} is not finite")
        if row["us_per_call"] < 0:
            raise ValueError(f"row {row['name']!r}: negative us_per_call")
        if payload["suite"] == "influence":
            # the uncertainty row's claim is the overhead vs plain decode —
            # it must carry the plain baseline and a finite overhead frac
            if row["name"].startswith("uncertainty"):
                for field in ("plain_us", "overhead_frac"):
                    v = row.get(field)
                    if not isinstance(v, (int, float)) or not math.isfinite(v):
                        raise ValueError(
                            f"influence row {row['name']!r}: {field} = "
                            f"{v!r} is not finite")
        if payload["suite"] == "serving":
            # TTFT (queueing + prefill) and decode-step latency are separate
            # distributions; a serving row must carry both percentile pairs
            for field in ("ttft_p50_ms", "ttft_p99_ms",
                          "decode_p50_ms", "decode_p99_ms"):
                v = row.get(field)
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    raise ValueError(
                        f"serving row {row['name']!r}: {field} = {v!r} "
                        f"is not a finite latency")
    return payload


def emit_json(path, suite: str, rows, extras=None) -> None:
    """Write one suite's rows as the BENCH_*.json documented above."""
    with open(path, "w") as f:
        json.dump(build_payload(suite, rows, extras), f, indent=1)
        f.write("\n")


def partially_train(steps=12, dims=None):
    """A partially-trained autoencoder + live K-FAC state (the paper's Fig. 7
    setup uses the iteration-500 network; we use a miniature analogue)."""
    dims = dims or DIMS
    mlp = MLP(dims, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    data = SyntheticAutoencoderData(dims[0], 8, 1024, seed=7)
    batch = data.batch(0)
    cfg = KFACConfig(lambda_init=3.0, t3=5)
    opt = KFAC(mlp, cfg, family="bernoulli")
    state = opt.init(params, batch)
    for step in range(steps):
        rng = jax.random.PRNGKey(1000 + step)
        state, grads, _ = opt.stats_grads(state, params, batch, rng)
        if step % cfg.t3 == 0 or step < 3:
            state = opt.refresh_inverses(state)
        params, state, _ = opt.apply_update(state, params, grads, batch, rng)
    return mlp, params, batch, state
