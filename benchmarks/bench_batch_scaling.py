"""Paper Fig. 9 analogue: per-iteration progress vs mini-batch size.

The paper's finding: K-FAC-with-momentum's per-iteration progress grows
superlinearly with m (gradient noise is its limiter), unlike SGD.
"""
from __future__ import annotations

import jax

from repro.configs.base import KFACConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP

DIMS = [64, 32, 16, 32, 64]


def run(steps=20):
    rows = []
    data = SyntheticAutoencoderData(DIMS[0], 8, 2048, seed=11)
    for m in (64, 256, 1024):
        mlp = MLP(DIMS, nonlin="tanh", loss="bernoulli")
        params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
        cfg = KFACConfig(lambda_init=3.0, t3=5)
        opt = KFAC(mlp, cfg, family="bernoulli")
        state = opt.init(params, data.batch(0, m))
        stats = jax.jit(opt.stats_grads)
        refresh = jax.jit(opt.refresh_inverses)
        update = jax.jit(lambda s, p, g, b, r: opt.apply_update(s, p, g, b, r))
        first = last = None
        for step in range(steps):
            batch = data.batch(step, m)
            rng = jax.random.PRNGKey(77 + step)
            state, grads, metr = stats(state, params, batch, rng)
            if step % cfg.t3 == 0 or step < 3:
                state = refresh(state)
            params, state, _ = update(state, params, grads, batch, rng)
            if first is None:
                first = float(metr["loss"])
            last = float(metr["loss"])
        rows.append((f"kfac_batch{m}_progress", 0.0, first - last))
    return rows


if __name__ == "__main__":
    for name, us, val in run():
        print(f"{name},{us:.0f},{val:.4f}")
