"""Paper Fig. 2/3/5/6 analogue, numerically: on a tiny MLP,

  * how well does the Kronecker factorization F̃ capture the exact Fisher F?
  * is F̃⁻¹ (approximately) block-tridiagonal, even though F̃ itself is not?

Outputs relative errors / off-diagonal mass ratios instead of images.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import factors as FA
from repro.models.mlp import MLP

DIMS = [8, 6, 5, 4]


def exact_fisher(mlp, params, x, n_samples=0, key=0):
    """Exact F = E_x[ Jᵀ F_R J ] — Bernoulli F_R = diag(p(1-p)) is closed
    form, so no Monte-Carlo targets are needed (unlike the running estimator,
    which is MC by design per S5)."""
    def flat_logits(p, xi):
        return mlp.logits(p, xi[None])[0]

    def per_input(xi):
        jac = jax.jacrev(flat_logits)(params, xi)       # per-weight jacobians
        j = jnp.concatenate(
            [jac[f"W{i}"].reshape(jac[f"W{i}"].shape[0], -1)
             for i in range(mlp.n_layers)], axis=1)      # (n_out, n_params)
        z = flat_logits(params, xi)
        r = jax.nn.sigmoid(z) * (1.0 - jax.nn.sigmoid(z))
        return jnp.einsum("oi,o,oj->ij", j, r, j)

    f = 0.0
    n = x.shape[0]
    for i in range(n):
        f = f + per_input(x[i])
    return f / n


def kron_fisher(mlp, params, x, key=7):
    """F̃ from the layer factors (diag blocks only — the paper's F̆)."""
    batch = {"x": x, "y": x[:, :DIMS[-1]]}
    shapes = mlp.probe_shapes(jax.eval_shape(lambda b: b, batch))
    probes = mlp.make_probes(shapes)

    def f2(pr):
        (_, ls), aux = mlp.loss(params, pr, batch, jax.random.PRNGKey(key),
                                mode="collect")
        return ls, aux

    ls, vjp_fn, aux = jax.vjp(f2, probes, has_aux=True)
    (gp,) = vjp_fn(jnp.float32(1.0))
    n = x.shape[0]
    blocks = []
    for name in mlp.layer_order:
        m = mlp.metas[name]
        a = FA.outer_sum(aux["recs"][name]["a"], "full", 1) / n
        g = FA.g_from_cotangent(gp[name], m, n)
        blocks.append(jnp.kron(a, g))
    sizes = [b.shape[0] for b in blocks]
    total = sum(sizes)
    f = jnp.zeros((total, total))
    off = 0
    for b in blocks:
        f = f.at[off:off + b.shape[0], off:off + b.shape[0]].set(b)
        off += b.shape[0]
    return f, sizes


def block_mass(mat, sizes):
    """Mean |entry| per block of a block-partitioned matrix."""
    off = np.cumsum([0] + sizes)
    ell = len(sizes)
    out = np.zeros((ell, ell))
    for i in range(ell):
        for j in range(ell):
            blk = mat[off[i]:off[i + 1], off[j]:off[j + 1]]
            out[i, j] = float(jnp.mean(jnp.abs(blk)))
    return out


def run():
    mlp = MLP(DIMS, nonlin="tanh", loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    x = (jax.random.uniform(jax.random.PRNGKey(1), (256, DIMS[0])) > 0.5
         ).astype(jnp.float32)

    f = exact_fisher(mlp, params, x[:64], n_samples=24)
    f_kron, sizes = kron_fisher(mlp, params, x)

    # Fig. 2: diagonal blocks of F vs F̃ (relative Frobenius error)
    off = np.cumsum([0] + sizes)
    errs = []
    for i in range(len(sizes)):
        sl = slice(off[i], off[i + 1])
        fb, kb = f[sl, sl], f_kron[sl, sl]
        errs.append(float(jnp.linalg.norm(fb - kb) / jnp.linalg.norm(fb)))
    diag_err = float(np.mean(errs))

    # Fig. 3: the *inverse* Fisher is near-block-tridiagonal; F itself is not
    damp = 1e-3 * jnp.eye(f.shape[0])
    f_inv = jnp.linalg.inv(f + damp)
    m_f = block_mass(f, sizes)
    m_inv = block_mass(f_inv, sizes)

    def offtri_ratio(m):
        ell = m.shape[0]
        tri, far = [], []
        for i in range(ell):
            for j in range(ell):
                (tri if abs(i - j) <= 1 else far).append(m[i, j])
        return float(np.mean(far) / np.mean(tri))

    return [
        ("fisher_kron_diagblock_relerr", 0.0, diag_err),
        ("fisher_offtri_ratio_F", 0.0, offtri_ratio(m_f)),
        ("fisher_offtri_ratio_Finv", 0.0, offtri_ratio(m_inv)),
    ]


if __name__ == "__main__":
    for name, us, val in run():
        print(f"{name},{us:.0f},{val:.4f}")
