"""Roofline derivation from the dry-run artifacts (per arch x shape x mesh).

Terms per cell (TPU v5e targets):
  compute    = HLO_FLOPs_per_device / 197e12 [bf16 FLOP/s]
  memory     = HLO_bytes_per_device / 819e9  [HBM B/s]   (bytes_min: TPU-like
               fusion model — only dot/conv/collective/slice ops touch HBM;
               bytes_raw from the unfused CPU module is reported alongside)
  collective = moved_bytes_per_device / 50e9 [B/s per ICI link]

FLOPs/bytes come from the trip-count-aware HLO walker (repro.launch.hlo_cost)
over the SPMD-partitioned module, so they are already per-device.

MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device per step —
fwd+bwd of the weight matmuls only; the ratio to HLO_FLOPs exposes remat /
attention / K-FAC overhead (and for serve shapes we report per-token maths).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = Path(__file__).resolve().parent / "results"


def active_params(cfg) -> float:
    """Per-token active parameter count (MoE: top-k experts only)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    total = v * d * (1 if cfg.tie_embeddings else 2)
    from repro.models.lm import build_pattern
    from repro.models.ssm import dt_rank
    pattern = build_pattern(cfg)
    n_groups = cfg.n_layers // len(pattern)
    for spec in pattern:
        if spec.attn in ("global", "local"):
            total += n_groups * (d * cfg.q_dim * 2 + d * cfg.kv_dim * 2)
        elif spec.attn == "mamba":
            di = cfg.ssm_expand * d
            r = dt_rank(d)
            total += n_groups * (d * 2 * di + di * (r + 2 * cfg.ssm_state_dim)
                                 + r * di + di * d)
        elif spec.attn == "rwkv":
            total += n_groups * (5 * d * d + d * 64 + 64 * d
                                 + d * f + f * d + d * d)
        if spec.cross:
            total += n_groups * (d * cfg.q_dim * 2 + d * cfg.kv_dim * 2)
        if spec.mlp == "dense":
            total += n_groups * 3 * d * f
        elif spec.mlp == "moe":
            total += n_groups * (d * cfg.n_experts
                                 + cfg.top_k * 3 * d * f
                                 + (3 * d * f if cfg.moe_shared_expert else 0))
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (d * d * 4 + 3 * d * f)
    return float(total)


def model_flops(cfg, shape, n_chips: int) -> float:
    """6·N_active·D per device (train); serve shapes: 2·N_active per token."""
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / n_chips
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_act * tokens / n_chips


def load_cell(arch: str, shape: str, pod: str = "pod256"):
    fn = RESULTS / "dryrun" / pod / f"{arch}__{shape}.json"
    if not fn.exists():
        return None
    return json.loads(fn.read_text())


def cell_terms(rec, cfg, shape, n_chips=256):
    h = rec["hlo"]
    compute = h["flops"] / PEAK_FLOPS
    memory = h.get("bytes_min", h["bytes"]) / HBM_BW
    coll = h["collectives"]["total"] / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_chips)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops": h["flops"],
        "useful_ratio": mf / max(h["flops"], 1.0),
        "bytes_raw": h["bytes"],
        "step_s_bound": max(terms.values()),
        "roofline_fraction": (h["flops"] / PEAK_FLOPS) / max(terms.values()),
    }


def build_table(pod="pod256"):
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            rec = load_cell(arch, sname, pod)
            if rec is None:
                rows.append({"arch": arch, "shape": sname,
                             "status": "missing"})
                continue
            if rec.get("skipped"):
                rows.append({"arch": arch, "shape": sname,
                             "status": "skipped"})
                continue
            if "error" in rec:
                rows.append({"arch": arch, "shape": sname, "status": "FAIL"})
                continue
            row = {"arch": arch, "shape": sname, "status": "ok",
                   "compile_s": rec.get("lower_compile_seconds"),
                   **cell_terms(rec, cfg, shape)}
            rows.append(row)
    return rows


def markdown(rows):
    hdr = ("| arch | shape | compute(s) | memory(s) | collective(s) | "
           "dominant | 6ND/HLO | roofline frac |")
    sep = "|" + "---|" * 8
    out = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def kernel_rows(path=None):
    """Per-kernel achieved-vs-peak roofline from BENCH_kernels.json.

    Every measured kernel row carries its own ``flops``/``bytes`` cost model
    (benchmarks/bench_kernels.py); achieved FLOP/s and B/s over the measured
    wall-clock give the fractions of the TPU peaks.  Interpreter-mode rows
    (``backend == "pallas_interp"``) are reported with null fractions — the
    interpreter's wall-clock is correctness-only, and a fraction of the TPU
    peak computed from it would be noise dressed as data."""
    path = Path(path) if path else (
        Path(__file__).resolve().parent.parent / "BENCH_kernels.json")
    if not path.exists():
        return []
    payload = json.loads(path.read_text())
    out = []
    for row in payload.get("rows", ()):
        if "flops" not in row or "bytes" not in row:
            continue
        us = row["us_per_call"]
        if us <= 0:
            continue
        interp = row.get("backend") == "pallas_interp"
        achieved_fs = row["flops"] / (us * 1e-6)
        achieved_bs = row["bytes"] / (us * 1e-6)
        out.append({
            "name": row["name"],
            "backend": row.get("backend"),
            "tuned": row.get("tuned"),
            "achieved_flops_s": achieved_fs,
            "achieved_bytes_s": achieved_bs,
            "flop_fraction": None if interp else achieved_fs / PEAK_FLOPS,
            "bw_fraction": None if interp else achieved_bs / HBM_BW,
            "arithmetic_intensity": row["flops"] / max(row["bytes"], 1.0),
        })
    return out


def kernel_markdown(krows):
    hdr = ("| kernel | backend | tuned | GFLOP/s | GB/s | peak FLOP frac | "
           "peak BW frac | FLOP/byte |")
    out = [hdr, "|" + "---|" * 8]
    for r in krows:
        ff = "interp" if r["flop_fraction"] is None else \
            f"{r['flop_fraction']:.4f}"
        bf = "interp" if r["bw_fraction"] is None else \
            f"{r['bw_fraction']:.4f}"
        out.append(
            f"| {r['name']} | {r['backend']} | {r['tuned'] or '—'} | "
            f"{r['achieved_flops_s'] / 1e9:.2f} | "
            f"{r['achieved_bytes_s'] / 1e9:.2f} | {ff} | {bf} | "
            f"{r['arithmetic_intensity']:.1f} |")
    return "\n".join(out)


def run():
    rows = build_table()
    ok = [r for r in rows if r["status"] == "ok"]
    out = [("roofline_cells_ok", 0.0, float(len(ok)))]
    for r in ok:
        out.append((f"roofline_{r['arch']}_{r['shape']}_frac", 0.0,
                    r["roofline_fraction"]))
    krows = kernel_rows()
    out.append(("roofline_kernel_rows", 0.0, float(len(krows))))
    for r in krows:
        # compiled rows report the peak-FLOP fraction; interpreter rows the
        # (backend-agnostic) arithmetic intensity so the row still lands
        out.append((f"roofline_kernel_{r['name']}", 0.0,
                    r["flop_fraction"] if r["flop_fraction"] is not None
                    else r["arithmetic_intensity"]))
    RESULTS.mkdir(parents=True, exist_ok=True)
    md = markdown(rows)
    if krows:
        md += "\n\n## Kernel roofline (BENCH_kernels.json)\n\n" \
            + kernel_markdown(krows)
    (RESULTS / "roofline.md").write_text(md)
    (RESULTS / "roofline.json").write_text(
        json.dumps({"cells": rows, "kernels": krows}, indent=1))
    return out


if __name__ == "__main__":
    run()
    print((RESULTS / "roofline.md").read_text())
