"""Serving-engine load benchmark: continuous batching vs the slot-serial
reference, under a Poisson open-loop workload.

For each reduced config (smollm, gemma2) a seeded load generator draws
request arrival times from a Poisson process (exponential inter-arrivals)
and prompt lengths / decode budgets from small fixed sets (so the jitted
per-length prefill graphs compile once, during warmup).  The same request
stream is then served twice through the *identical* compute path:

* ``batched``  — ``Engine(batch_slots=4)``: continuous batching + paged KV
  cache, in-flight refills;
* ``serial``   — ``serial_engine`` (``batch_slots=1``): the reference that
  the batched engine must match token-for-token under greedy decoding
  (asserted here, not just in the tests).

Rows (BENCH_serving.json, benchlib schema):

* ``us_per_call`` — mean per-token latency in µs, where a token's latency
  is the wall-clock gap since the request's previous emission (submission
  for the first token — i.e. queueing shows up in the tail);
* ``derived``    — end-to-end decode throughput, tokens/s;
* meta          — TTFT and decode-step percentiles reported *separately*
  (``ttft_p50_ms`` / ``ttft_p99_ms`` measure submission -> first token,
  i.e. queueing + prefill; ``decode_p50_ms`` / ``decode_p99_ms`` measure
  the steady-state gap between a request's consecutive tokens — mixing
  the two in one distribution made p99 track prefill, not decode; both
  definitions live in ``repro.obs.latency.RequestLatencyTracker``, the
  same class the live engine records into, so bench rows and production
  metrics cannot diverge),
  ``n_tokens``, ``n_requests``, ``preemptions``, ``batch_slots`` and the
  ``backend`` label (``xla`` einsum fallback, or ``pallas`` /
  ``pallas_interp`` — interpret mode is labelled, never silently timed as
  a compiled kernel).

Engines are warmed on the same prompt-length set and ``reset()`` before the
timed run, so compile time never lands in a latency percentile.

CLI:  --quick   small workload (CI bench-smoke)
      --check   validate schema + batched >= 2x serial throughput on
                smollm + token-for-token parity batched vs serial
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.kernels import ops
from repro.models.lm import LM
from repro.obs import RequestLatencyTracker
from repro.serving.server import Engine, Request, serial_engine

# (config registry name, row prefix, decode window/cap live in the model)
_CONFIGS = [("smollm-135m", "smollm"), ("gemma2-2b", "gemma2")]
_SLOTS = 4          # batched engine width
_MAX_LEN = 48       # per-slot KV budget (reduced configs)
_PAGE = 8


def _backend_label() -> str:
    if not ops.enabled():
        return "xla"
    return "pallas_interp" if ops._STATE["interpret"] else "pallas"


def _workload(cfg, n_requests: int, seed: int = 0):
    """Deterministic Poisson stream: (requests, arrival_times_s).

    Prompt lengths / max_new come from small fixed sets so warmup can
    pre-compile every per-length prefill graph the timed run will hit.
    """
    rs = np.random.RandomState(seed)
    lens = [4, 8, 12, 16]
    # decode-heavy budgets: prefill is inherently serial (batch-1) in both
    # engines, so short decodes would Amdahl the batching win away
    news = [16, 24, 32]
    arrivals = np.cumsum(rs.exponential(scale=0.002, size=n_requests))
    reqs = []
    for u in range(n_requests):
        tp = lens[rs.randint(len(lens))]
        reqs.append(Request(
            uid=u,
            prompt=[int(t) for t in rs.randint(0, cfg.vocab_size, size=tp)],
            max_new=news[rs.randint(len(news))]))
    return reqs, arrivals


def _clone(reqs):
    return [Request(uid=r.uid, prompt=list(r.prompt), max_new=r.max_new,
                    temperature=r.temperature, top_k=r.top_k,
                    top_p=r.top_p, seed=r.seed) for r in reqs]


def _drive(engine: Engine, reqs, arrivals):
    """Open-loop serve: submit each request at its arrival time, step until
    drained.  Returns (tracker, elapsed [s], n_tokens, preemptions).

    The TTFT / decode-gap split is *not* re-derived here — it comes from
    :class:`repro.obs.RequestLatencyTracker`, the single definition the
    live engine telemetry also records against: a request's first emission
    measures submission -> first token (queueing + prefill); subsequent
    emissions measure the steady-state decode-step gap.  The two are kept
    apart: one mixed distribution makes p99 track prefill, not decode."""
    lat = RequestLatencyTracker()
    pending = list(zip(reqs, arrivals))
    t0 = time.time()
    while pending or not engine.idle:
        now = time.time() - t0
        while pending and pending[0][1] <= now:
            req, _ = pending.pop(0)
            if engine.submit(req):
                lat.on_submit(req.uid)
        if engine.idle:
            if pending:                      # wait out the next arrival
                time.sleep(max(0.0, min(1e-3, pending[0][1] - now)))
            continue
        ems = engine.step_once()
        t = time.time()
        for req, _tok in ems:
            lat.on_emit(req.uid, t)
    n_pre = sum(r.preemptions for r in reqs)
    return lat, time.time() - t0, lat.n_tokens, n_pre


def _bench_engine(engine: Engine, reqs, arrivals):
    # warmup compiles every per-length prefill + the batched step, then the
    # serving state is wiped so the timed runs start cold-cache, warm-jit
    engine.run(_clone(reqs), max_steps=10_000)
    # best of two timed drives: a single open-loop pass on a shared CPU
    # host is exposed to GC/scheduler hiccups that have nothing to do with
    # the engine under test
    best = None
    for _ in range(2):
        engine.reset()
        r = _drive(engine, _clone(reqs), arrivals)
        if best is None or r[2] / r[1] > best[2] / best[1]:
            best = r
    lat, elapsed, n, n_pre = best
    all_ms = [x * 1e3 for x in lat.ttft_s + lat.decode_s]
    return {
        "us_per_call": float(np.mean(all_ms) * 1e3),
        "derived": n / elapsed,                        # tokens/s
        # percentiles come from the tracker — the same definition (and the
        # same exact-percentile arithmetic) the live engine records
        "meta": {**lat.percentiles(),
                 "n_tokens": n, "n_requests": len(reqs),
                 "preemptions": n_pre,
                 "batch_slots": engine.b,
                 "backend": _backend_label()},
    }


def run(quick: bool = False):
    """Yield benchlib rows; also used by benchmarks/run.py."""
    n_requests = 6 if quick else 24
    for reg_name, prefix in _CONFIGS:
        cfg = get_reduced_config(reg_name)
        lm = LM(cfg)
        params = lm.init_params(jax.random.PRNGKey(0))
        reqs, arrivals = _workload(cfg, n_requests)
        for mode, eng in (
                ("batched%d" % _SLOTS,
                 Engine(lm, params, batch_slots=_SLOTS, max_len=_MAX_LEN,
                        page_size=_PAGE)),
                ("serial",
                 serial_engine(lm, params, max_len=_MAX_LEN,
                               page_size=_PAGE))):
            r = _bench_engine(eng, _clone(reqs), arrivals)
            yield (f"{prefix}_{mode}", r["us_per_call"], r["derived"],
                   r["meta"])


def _check(rows) -> None:
    from benchmarks import benchlib
    payload = benchlib.build_payload("serving", rows)
    benchlib.validate_rows(payload)
    by_name = {r[0]: r for r in rows}
    batched = by_name[f"smollm_batched{_SLOTS}"]
    serial = by_name["smollm_serial"]
    if batched[2] < 2.0 * serial[2]:
        raise SystemExit(
            f"continuous batching under-delivers: {batched[2]:.1f} tok/s "
            f"batched vs {serial[2]:.1f} tok/s serial (< 2x)")
    print(f"[check] schema ok; smollm batched/serial throughput = "
          f"{batched[2] / serial[2]:.2f}x")


def _check_parity(quick: bool) -> None:
    """Batched vs slot-serial greedy outputs must be token-identical."""
    n_requests = 6 if quick else 24
    for reg_name, prefix in _CONFIGS:
        cfg = get_reduced_config(reg_name)
        lm = LM(cfg)
        params = lm.init_params(jax.random.PRNGKey(0))
        reqs, _ = _workload(cfg, n_requests)
        a, b = _clone(reqs), _clone(reqs)
        Engine(lm, params, batch_slots=_SLOTS, max_len=_MAX_LEN,
               page_size=_PAGE).run(a, max_steps=10_000)
        serial_engine(lm, params, max_len=_MAX_LEN, page_size=_PAGE).run(
            b, max_steps=10_000)
        for ra, rb in zip(a, b):
            if ra.out != rb.out:
                raise SystemExit(
                    f"{prefix} uid={ra.uid}: batched {ra.out} != "
                    f"serial {rb.out}")
        print(f"[check] {prefix}: batched == serial token-for-token "
              f"({len(a)} requests)")


def main() -> None:
    import argparse
    import os

    from benchmarks import benchlib

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()
    rows = list(run(quick=args.quick))
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.0f},{row[2]:.4f}")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    benchlib.emit_json(os.path.join(root, "BENCH_serving.json"),
                       "serving", rows)
    if args.check:
        _check(rows)
        _check_parity(args.quick)


if __name__ == "__main__":
    main()
