"""Validate an obs JSONL event log — the CI gate over instrumented runs.

Usage::

    python -m benchmarks.obs_check /tmp/train.jsonl \
        --expect train_step,kfac_step,refresh

Every line must parse as a schema-valid event
(``repro.obs.export.validate_event`` — version tag, finite timestamp,
the event type's required fields, finite numbers throughout); the
``--expect`` list additionally requires at least one event of each named
type to be present.  Exits non-zero (with the offending line number /
missing type) on any violation — CI uploads the log as an artifact either
way, so a red run still leaves the evidence behind.
"""
from __future__ import annotations

import argparse
import sys
from collections import Counter

from repro.obs.export import read_jsonl


def check(path: str, expect=()) -> Counter:
    """Validate ``path``; returns the per-event-type counts.  Raises
    ValueError on a malformed line or a missing expected type."""
    events = read_jsonl(path)
    if not events:
        raise ValueError(f"{path}: no events")
    counts = Counter(e["event"] for e in events)
    missing = [t for t in expect if counts[t] == 0]
    if missing:
        raise ValueError(
            f"{path}: expected event type(s) never emitted: {missing} "
            f"(saw {dict(counts)})")
    return counts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("path", help="JSONL event log to validate")
    ap.add_argument("--expect", default="",
                    help="comma-separated event types that must appear "
                         "at least once (e.g. train_step,kfac_step)")
    args = ap.parse_args(argv)
    expect = [t for t in args.expect.split(",") if t]
    try:
        counts = check(args.path, expect)
    except (OSError, ValueError) as e:
        print(f"[obs_check] FAIL: {e}", file=sys.stderr)
        return 1
    total = sum(counts.values())
    detail = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"[obs_check] ok: {total} events ({detail})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
