"""Deprecated import path — the K-FAC implementation moved.

``KFAC`` is now :class:`repro.optimizers.kfac.KFACEngine`: the same stage
methods (``stats_grads`` / ``refresh_inverses`` / ``rescale_step`` /
``apply_update`` / ``lambda_step``), operating on the typed
:class:`repro.core.transform.KFACState` instead of a raw dict (dict-style
reads like ``state["lam"]`` still work).  Hand-driving the stages remains
supported, but the one-call pipeline is the front door now::

    from repro import optimizers
    opt = optimizers.kfac(model, cfg)                 # Optimizer(init, update)
    state = opt.init(params, batch)
    params, state, metrics = opt.update(None, state, params, batch, rng)

``KFAC(model, cfg)`` instances passed to ``Trainer`` are wrapped into that
pipeline automatically.  See ``docs/optimizer_api.md`` for the stage map.
"""
from __future__ import annotations

from repro.optimizers.kfac import KFACEngine as KFAC

__all__ = ["KFAC"]
