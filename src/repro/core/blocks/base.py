"""Curvature-block abstraction (paper S3–S4): one object per Fisher block.

The block-diagonal Fisher approximation assigns every tagged layer its own
Kronecker-factored block ``F_i ≈ Ā_i ⊗ G_i``.  :class:`CurvatureBlock` owns
everything per-layer the optimizer used to branch on ``meta.kind`` for:

  * factor layout + zero/identity initialization and sharding specs,
  * the per-step statistics contribution and decayed blend (S5),
  * the damped factor inverses (S4.2 / S6.3),
  * the preconditioner apply ``U = Ā⁻¹ V G⁻¹``.

Concrete subclasses live in :mod:`repro.core.blocks.kron` (dense /
TP-blocked / diagonal Kronecker pairs), :mod:`repro.core.blocks.special`
(embedding, LM head, MoE expert) and :mod:`repro.core.blocks.chain`
(the block-tridiagonal chain, S4.3).  Classes self-register against the
``LayerMeta.kind`` values they serve; :func:`build_blocks` resolves one
block instance per tagged layer.  Adding a new block family (EKFAC
eigenbasis blocks, convolution blocks, ...) is one new registered class —
no edits to the optimizer.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, List, Optional, Type

import jax
import jax.numpy as jnp

from repro.core import factors as F
from repro.core import inverse as INV
from repro.core.tags import LayerMeta


class CurvatureBlock(abc.ABC):
    """One layer's Fisher block: layout, statistics, inverse, apply."""

    kinds: tuple = ()   # LayerMeta.kind values this class can serve
    priority: int = 0   # higher wins when several classes claim a kind

    def __init__(self, meta: LayerMeta, cfg):
        self.meta = meta
        self.cfg = cfg

    @classmethod
    def handles(cls, meta: LayerMeta) -> bool:
        """Refine registry dispatch beyond `kind` (e.g. on factor layout)."""
        return True

    # ------------------------------------------------------------------
    # kernel routing
    # ------------------------------------------------------------------
    @property
    def backend(self) -> str:
        return getattr(self.cfg, "kernel_backend", "xla")

    @property
    def autotune_mode(self) -> str:
        return getattr(self.cfg, "autotune", "off")

    @staticmethod
    def _interpret() -> bool:
        return jax.default_backend() != "tpu"

    def _tuned(self, kernel: str, shape, dtype) -> dict:
        """Autotuned tile kwargs for ``kernel`` on this problem, or ``{}``
        (kernel defaults) when tuning is off / no candidate is legal."""
        from repro.kernels.autotune import tuned
        return tuned(kernel, shape, dtype, interpret=self._interpret(),
                     mode=self.autotune_mode) or {}

    # ------------------------------------------------------------------
    # layout
    # ------------------------------------------------------------------
    @property
    def lead(self) -> tuple:
        m = self.meta
        lead = ()
        if m.n_stack:
            lead += (m.n_stack,)
        if m.n_expert:
            lead += (m.n_expert,)
        return lead

    def init_factors(self) -> Dict[str, Any]:
        m = self.meta
        return {
            "a": jnp.zeros(F.factor_shape(m.a_dim, m.a_kind, m.a_blocks,
                                          self.lead), jnp.float32),
            "g": jnp.zeros(F.factor_shape(m.g_dim, m.g_kind, m.g_blocks,
                                          self.lead), jnp.float32),
        }

    def identity_inverse(self) -> Dict[str, Any]:
        z = self.init_factors()

        def one(arr, kind):
            if kind == "diag":
                return jnp.ones_like(arr)
            return arr + jnp.eye(arr.shape[-1], dtype=jnp.float32)

        return {"a_inv": one(z["a"], self.meta.a_kind),
                "g_inv": one(z["g"], self.meta.g_kind)}

    def factor_specs(self, mesh) -> Dict[str, Any]:
        """Storage shardings for this block's factor/inverse state.

        Stacked/expert/block lead dims go over `model` where aligned; the
        matrix dim that CONTRACTS against the grad during preconditioning is
        FSDP-sharded over `data` (A: columns, G: rows) so ``U = Ā⁻¹ V G⁻¹``
        needs no gathers — just a small partial-sum all-reduce.
        """
        from jax.sharding import PartitionSpec as P
        from repro.utils.sharding import pick_shard
        m = self.meta

        def one(dim, kind, blocks, side):
            lead = []
            if m.n_stack:
                lead.append(None)
            if m.n_expert:
                lead.append(pick_shard(m.n_expert, mesh, "model"))
            if kind == "diag":
                return P(*lead, pick_shard(dim, mesh, "data"))
            if kind == "block":
                return P(*lead, pick_shard(blocks, mesh, "model"),
                         pick_shard(dim // blocks, mesh, "data"), None)
            if side == "a":
                return P(*lead, None, pick_shard(dim, mesh, "data"))
            return P(*lead, pick_shard(dim, mesh, "data"), None)

        return {"a": one(m.a_dim, m.a_kind, m.a_blocks, "a"),
                "g": one(m.g_dim, m.g_kind, m.g_blocks, "g")}

    # ------------------------------------------------------------------
    # statistics (S5)
    # ------------------------------------------------------------------
    @abc.abstractmethod
    def stats_contrib(self, rec, gprobe, batch, n: int) -> Dict[str, Any]:
        """This step's (1/N-normalized) factor contribution {"a", "g"}."""

    def update_factors(self, old, rec, gprobe, batch, n: int, eps):
        """Decayed blend ``C ← ε C + (1−ε) contrib``; ε may be traced."""
        return F.blend(old, self.stats_contrib(rec, gprobe, batch, n), eps)

    # ------------------------------------------------------------------
    # inverses (S4.2 / S6.3)
    # ------------------------------------------------------------------
    def damped_inverse(self, fac, gamma, *, method: str = "eigh",
                       iters: int = 12, prev: Optional[Dict] = None):
        return INV.damped_pair_inverse(self.meta, fac["a"], fac["g"], gamma,
                                       method=method, iters=iters, prev=prev)

    # ------------------------------------------------------------------
    # preconditioning
    # ------------------------------------------------------------------
    def precondition(self, inv, v):
        """``U = Ā⁻¹ V G⁻¹`` with this block's structure; v shaped like W."""
        return INV.apply_block_inverse(self.meta, inv, v)

    def precond_momentum(self, inv, v, mom, alpha, mu, eigen: bool = False):
        """Fused update chain for the fixed-lr path (S4.2 + S7):
        ``D = alpha·precondition(v) + mu·mom`` plus ``Σ D²`` — the squared
        norm comes out of the same pass so the global-norm clip never
        re-reads the update.  Subclasses may serve this with one kernel."""
        u = (self.precondition_eigen(inv, v) if eigen
             else self.precondition(inv, v))
        d = alpha * u.astype(jnp.float32) + mu * mom
        return d, jnp.sum(d * d)

    # ------------------------------------------------------------------
    # eigenbasis (EKFAC) path — George et al. 1806.03884
    # ------------------------------------------------------------------
    def eigen_state(self, fac, gamma):
        """Amortized refresh: factor eigenbases + eigenbasis diagonals
        ``{"qa", "qg", "s", "damp"}`` (``qa``/``qg`` None on diag sides)."""
        return INV.eigen_pair_state(self.meta, fac["a"], fac["g"], gamma)

    def eigen_identity(self):
        """Pre-refresh placeholder with the post-refresh pytree structure:
        identity bases and a unit diagonal (an identity preconditioner)."""
        z = self.init_factors()

        def basis(arr, kind):
            if kind == "diag":
                return None
            return arr + jnp.eye(arr.shape[-1], dtype=jnp.float32)

        m = self.meta
        diag_shape = (*self.lead, m.a_dim, m.g_dim)
        return {"qa": basis(z["a"], m.a_kind), "qg": basis(z["g"], m.g_kind),
                "s": jnp.ones(diag_shape, jnp.float32),
                "damp": jnp.zeros(diag_shape, jnp.float32)}

    def eigen_state_multi(self, fac, gammas):
        """Candidate-stacked eigen states (gamma sweep) from one eigh."""
        return INV.eigen_pair_multi(self.meta, fac["a"], fac["g"], gammas)

    def rescale_step(self, eig, grad, eps):
        """Per-step second-moment update ``s ← εs + (1−ε)(Q_Aᵀ ∇ Q_G)²``."""
        return INV.eigen_rescale(self.meta, eig, grad, eps)

    def precondition_eigen(self, eig, v):
        """``U = Q_A [ (Q_Aᵀ V Q_G) / (s + damp) ] Q_Gᵀ``; v shaped like W."""
        return INV.apply_eigen(self.meta, eig, v)

    def ihvp(self, eig, v):
        """Inverse-Hessian-vector product against this block's damped
        Kronecker Fisher — the eigen apply, exposed under the name the
        influence service uses (``curvature/ihvp.py``)."""
        return self.precondition_eigen(eig, v)

    def ihvp_batched(self, eig, vs):
        """Batched iHVP over a stack of queries (leading ``N`` axis).

        The explicit outer vmap is load-bearing: subclasses' internal
        stacked-layer vmaps close over *all* args, so mapping only ``vs``
        here keeps the shared eigen state un-batched while the Pallas
        ``rotate_rescale`` route rides underneath unchanged."""
        return jax.vmap(lambda v: self.precondition_eigen(eig, v))(vs)

    def eigen_specs(self, mesh) -> Dict[str, Any]:
        """Storage shardings for the eigen state: bases shard like their
        factors; the eigenbasis diagonals shard their d_in axis over `data`
        like the weight (no gathers in the rotate/rescale apply)."""
        from jax.sharding import PartitionSpec as P
        from repro.utils.sharding import pick_shard
        m = self.meta
        fs = self.factor_specs(mesh)
        lead = []
        if m.n_stack:
            lead.append(None)
        if m.n_expert:
            lead.append(pick_shard(m.n_expert, mesh, "model"))
        diag = P(*lead, pick_shard(m.a_dim, mesh, "data"), None)
        return {"qa": None if m.a_kind == "diag" else fs["a"],
                "qg": None if m.g_kind == "diag" else fs["g"],
                "s": diag, "damp": diag}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, List[Type[CurvatureBlock]]] = {}


def register(cls: Type[CurvatureBlock]) -> Type[CurvatureBlock]:
    """Class decorator: file ``cls`` under every kind it serves."""
    for kind in cls.kinds:
        lst = _REGISTRY.setdefault(kind, [])
        lst.append(cls)
        lst.sort(key=lambda c: -c.priority)
    return cls


def registered(kind: str) -> List[Type[CurvatureBlock]]:
    return list(_REGISTRY.get(kind, ()))


def resolve(meta: LayerMeta) -> Type[CurvatureBlock]:
    for cls in _REGISTRY.get(meta.kind, ()):
        if cls.handles(meta):
            return cls
    raise KeyError(f"no curvature block registered for kind={meta.kind!r} "
                   f"(layer {meta.name!r}); known kinds: {sorted(_REGISTRY)}")


def build_blocks(metas: Dict[str, LayerMeta], cfg) -> Dict[str, CurvatureBlock]:
    """One resolved block instance per tagged layer."""
    return {name: resolve(m)(m, cfg) for name, m in metas.items()}
