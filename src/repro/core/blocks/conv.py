"""KFC convolution curvature blocks (Grosse & Martens, arXiv:1602.01407).

A conv layer's Fisher block is Kronecker-factored over *patches*: with the
weight stored as a ``(prod(K)·C [+1], d_out)`` matrix over tap-major im2col
features (see :mod:`repro.models.conv`), the approximation is

  * ``Ā`` — the spatially-averaged patch second moment
    ``(1/N) Σ_{b,t} â_{bt} â_{bt}ᵀ`` with the homogeneous coordinate
    ``â = [patch; 1]`` carrying the bias row/column, and
  * ``G``  — the pre-activation gradient second moment averaged over the
    same spatial locations, ``(1/N) Σ_{b,t} g_{bt} g_{bt}ᵀ``

— i.e. every spatial output location is a "token", exactly how the dense
blocks treat sequence positions (KFC's SUA assumption: spatially
uncorrelated derivatives).  Both sides use the optimizer's global-N
normalization; the c·Ā ⊗ (1/c)·G ambiguity this leaves is annihilated by
the factored-Tikhonov trace norm π (S6.3), so the damped preconditioner is
normalization-independent.

The record carries only the RAW conv input (``{"cx": x}`` from
``Tagger.tag_conv``); patches are extracted here — on the XLA path via
``jax.lax.conv_general_dilated_patches``, on the Pallas path fused into the
factor accumulation itself (:mod:`repro.kernels.patch_factor`), so the
im2col buffer is never materialized in HBM during the stats pass.  Since
the weight is a plain matrix, everything else — damped ``eigh``/``ns``
inverses, the EKFAC eigen state + per-step ``rescale_step``, and the Pallas
``precondition`` / ``rotate_rescale`` routes — is inherited from
:class:`DenseKronecker` unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.blocks.base import register
from repro.core.blocks.kron import DenseKronecker
from repro.kernels.patch_factor import patch_factor_update


@register
class ConvKronecker(DenseKronecker):
    """KFC conv block: patch-factor statistics over spatial locations."""

    kinds = ("conv",)
    priority = 10

    def patches(self, rec):
        """im2col of the recorded raw input, flattened over (batch, space),
        with the homogeneous coordinate appended when the layer has a bias.
        A record already in dense form (``{"a": patches}``, as produced by
        the delegating paths below) passes through unchanged."""
        if "cx" not in rec:
            return rec["a"]
        from repro.models.conv import append_homog, extract_patches
        m = self.meta
        p = extract_patches(rec["cx"], m.conv_spatial, m.conv_stride,
                            m.conv_pad)
        p = p.reshape(-1, p.shape[-1])
        return append_homog(p) if m.has_bias else p

    def stats_contrib(self, rec, gprobe, batch, n):
        # dense-form record over the extracted patches; an already-contracted
        # record (fused_stats, {"aa"}) passes straight through — the shared
        # KroneckerPair numerics handle every per-side factor kind
        dense_rec = rec if "aa" in rec else {"a": self.patches(rec)}
        return super().stats_contrib(dense_rec, gprobe, batch, n)

    def update_factors(self, old, rec, gprobe, batch, n, eps):
        m = self.meta
        one = jnp.float32(1.0)
        a_new = None
        if (self.backend == "pallas" and not self.lead and m.a_kind == "full"
                and m.g_kind == "full" and "cx" in rec
                and rec["cx"].ndim == 3):
            # 1-D conv: fused im2col + factor update straight from the raw
            # input — the im2col buffer never hits HBM (declines to None on
            # shapes that don't tile)
            a_new = patch_factor_update(rec["cx"], old["a"], m,
                                        (one - eps) / n, eps,
                                        interpret=self._interpret(),
                                        autotune_mode=self.autotune_mode)
        if a_new is None:
            # everything else is exactly the dense route: pre-contracted
            # fused records pass through, 2-D patchifiers (their im2col is a
            # reshape, no blowup) and ragged shapes fall back inside
            # DenseKronecker over the extracted patches
            dense_rec = rec if "aa" in rec else {"a": self.patches(rec)}
            return super().update_factors(old, dense_rec, gprobe, batch, n,
                                          eps)
        # A fused; G identically to the dense route — cotangents of the
        # (1/N)-normalized sampled loss over every spatial location
        return {"a": a_new, "g": self._g_side(old["g"], gprobe, n, eps)}
