# Curvature-block registry: per-layer Fisher blocks behind one interface.
# Importing the package registers every built-in block class. See README.md.
from repro.core.blocks.base import (CurvatureBlock, build_blocks, register,
                                    registered, resolve)
from repro.core.blocks.chain import TridiagChain
from repro.core.blocks.conv import ConvKronecker
from repro.core.blocks.kron import (BlockDiagKronecker, DenseKronecker,
                                    DiagFactor, KroneckerPair)
from repro.core.blocks.special import Embed, Expert, Head

__all__ = [
    "CurvatureBlock", "KroneckerPair", "DenseKronecker", "BlockDiagKronecker",
    "DiagFactor", "ConvKronecker", "Embed", "Head", "Expert", "TridiagChain",
    "register", "registered", "resolve", "build_blocks",
]
