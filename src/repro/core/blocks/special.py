"""Curvature blocks for the non-dense layer families.

  * :class:`Embed`  — embedding lookups: Ā is the diagonal of token
    frequencies (a one-hot input's second moment), G is dense on d_model.
  * :class:`Head`   — the LM head: the model records a contracted ``aa``
    over hidden states and a diagonal ``gdiag`` over the vocab side (the
    full vocab² G would be unstorable).
  * :class:`Expert` — MoE experts: per-expert factors over the tokens routed
    to each expert, with the routing probability baked into the factor via
    global-N normalization (rarely-hit experts get small factors and the
    damping floor dominates — the consistent Fisher treatment).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import factors as F
from repro.core.blocks.base import CurvatureBlock, register
from repro.core.blocks.kron import KroneckerPair


@register
class Embed(CurvatureBlock):
    """Embedding block: diagonal Ā of token counts, dense G."""

    kinds = ("embed",)

    def stats_contrib(self, rec, gprobe, batch, n):
        m = self.meta
        tokens = batch["tokens"]
        mask = batch.get("mask", jnp.ones(tokens.shape, jnp.float32))
        a_c = F.embed_diag_counts(tokens, mask, m.d_in) / n
        g_c = F.g_from_cotangent(gprobe, m, n)
        return {"a": a_c, "g": g_c}


@register
class Head(CurvatureBlock):
    """LM-head block: contracted dense Ā, diagonal vocab-side G.

    Both statistics are produced inside the model's chunked head loss
    (see models/head.py), pre-normalized on the G side.
    """

    kinds = ("head",)

    def stats_contrib(self, rec, gprobe, batch, n):
        return {"a": rec["aa"] / n, "g": rec["gdiag"]}


@register
class Expert(KroneckerPair):
    """Per-expert Kronecker factors; inherits the generic pair numerics
    (outer_sum carries the expert axis; lead dims block the Pallas route)."""

    kinds = ("expert",)
