"""Block-tridiagonal chain block (paper S4.3, Appendix B).

Chain models (the paper's MLP/autoencoder family) support the richer
tridiagonal inverse approximation ``F̂⁻¹ = Ξᵀ Λ Ξ``, which couples
consecutive layers through cross moments ``Ā_{i,i+1}``, ``G_{i,i+1}``.
That coupling does not fit the one-layer :class:`CurvatureBlock` contract
exactly, so :class:`TridiagChain` is the chain-level analogue: its "factor"
state is the cross-moment dict stored under the ``__cross__`` key, its
"inverse" is the precomputed Ψ/Σ cache stored under ``__tri__``, and its
apply preconditions *all* chain layers at once (the per-layer blocks still
own the diagonal factors it reads).  Numerics live in ``core.tridiag``.
"""
from __future__ import annotations

from typing import Dict

from repro.core import factors as F
from repro.core import tridiag as TRI
from repro.core.blocks.base import CurvatureBlock, register


@register
class TridiagChain(CurvatureBlock):
    """Chain-spanning tridiagonal block; pytree-valued where the per-layer
    blocks are array-valued (see module docstring)."""

    kinds = ("tridiag",)

    CROSS = "__cross__"   # factors-dict key for the cross moments
    TRI = "__tri__"       # inverse-dict key for the Ψ/Σ cache

    def __init__(self, model, cfg):
        if not hasattr(model, "layer_order"):
            # registry dispatch hands per-layer blocks a LayerMeta; this
            # block spans a chain and must be built with the model itself
            raise TypeError(
                "TridiagChain needs a chain model with .layer_order; it is "
                "not a per-layer block — construct it as "
                "TridiagChain(model, cfg), not through build_blocks()")
        super().__init__(meta=None, cfg=cfg)
        self.model = model

    # -- layout ---------------------------------------------------------
    def init_factors(self) -> Dict:
        return TRI.init_cross_state(self.model)

    def identity_inverse(self):
        return None          # populated at the first refresh

    # -- statistics -----------------------------------------------------
    def stats_contrib(self, recs, gprobes, batch, n):
        """Cross-moment contribution; takes the *full* record/probe dicts."""
        return TRI.cross_contrib(self.model, recs, gprobes, n)

    def update_factors(self, old, recs, gprobes, batch, n, eps):
        return F.blend(old, self.stats_contrib(recs, gprobes, batch, n), eps)

    # -- inverses -------------------------------------------------------
    def damped_inverse(self, factors, gamma, **_):
        """Ψ/Σ precomputation over the whole factors dict (diagonal blocks
        plus this block's cross moments under CROSS)."""
        return TRI.precompute(self.model, factors, gamma, self.cfg.eta)

    # -- preconditioning ------------------------------------------------
    def precondition(self, tri, vs: Dict):
        """``U = F̂⁻¹ V`` for every chain layer; vs keyed by layer name."""
        return TRI.apply(self.model, tri, vs)
