"""Kronecker-pair curvature blocks for dense linear maps (paper S3–S4.2).

Three concrete layouts, resolved from ``LayerMeta``'s per-side factor kinds:

  * :class:`DenseKronecker`    — both factors dense (``full``/``full``).
    The hot path: when ``kernel_backend == "pallas"`` and shapes tile, the
    decayed factor accumulation runs through the fused
    :func:`repro.kernels.factor_update.factor_update` kernel and the
    two-sided apply through :func:`repro.kernels.precond.precondition`.
  * :class:`BlockDiagKronecker` — at least one TP-blocked side (DESIGN §3).
  * :class:`DiagFactor`         — at least one diagonal side (dims above
    ``max_factor_dim``).

All three share the per-side numerics in ``core.factors`` / ``core.inverse``;
the subclasses differ in dispatch and in which paths may route to Pallas.
Ragged shapes (or sides without raw activations) silently fall back to the
einsum path, so the choice of backend never changes results — only kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import factors as F
from repro.core.blocks.base import CurvatureBlock, register
from repro.kernels.compat import tile_ok
from repro.kernels.factor_update import factor_update
from repro.kernels.precond import precondition as precond_kernel
from repro.kernels.rotate_rescale import rotate_rescale
from repro.kernels.update_chain import precond_momentum as chain_kernel


class KroneckerPair(CurvatureBlock):
    """Shared statistics/inverse/apply logic for two-sided Kronecker blocks."""

    def stats_contrib(self, rec, gprobe, batch, n):
        m = self.meta
        if "aa" in rec:              # contracted in-forward (scan models /
            a_c = rec["aa"] / n      # fused_stats)
        else:
            a_c = F.outer_sum(rec["a"], m.a_kind, m.a_blocks,
                              expert=m.kind == "expert") / n
        if isinstance(gprobe, dict):
            # fused_stats: the backward already contracted Σ cot cotᵀ (see
            # repro.core.fused.apply_gprobe); same N-scaling as
            # g_from_cotangent
            g_c = gprobe["gg"] * float(n)
        else:
            g_c = F.g_from_cotangent(gprobe, m, n)
        return {"a": a_c, "g": g_c}


@register
class DiagFactor(KroneckerPair):
    """A diagonal factor on at least one side (vocab-scale dims)."""

    kinds = ("dense",)
    priority = 30

    @classmethod
    def handles(cls, meta):
        return "diag" in (meta.a_kind, meta.g_kind)


@register
class BlockDiagKronecker(KroneckerPair):
    """A TP-block-diagonal factor on at least one side."""

    kinds = ("dense",)
    priority = 20

    @classmethod
    def handles(cls, meta):
        return "block" in (meta.a_kind, meta.g_kind)


@register
class DenseKronecker(KroneckerPair):
    """Dense ``full``/``full`` Kronecker pair — the Pallas hot path."""

    kinds = ("dense",)
    priority = 10

    # -- fused stats accumulation (S5 through the factor_update kernel) --
    def _pallas_side(self, x, old, alpha, eps):
        """One side's fused ``C ← ε C + α XᵀX`` if X tiles, else None."""
        if x is None:
            return None
        x2 = x.reshape(-1, x.shape[-1])
        if not tile_ok(*x2.shape):
            return None
        cfg = self._tuned("factor_update", x2.shape, x2.dtype)
        return factor_update(x2, old, alpha=alpha, beta=eps,
                             interpret=self._interpret(), **cfg)

    def _g_side(self, old_g, gprobe, n, eps):
        """G side of the decayed blend: cotangents of the (1/N)-normalized
        sampled loss; per-token g = N·cot, so G = (1/N) Σ g gᵀ = N Σ cot cotᵀ.
        A fused ``{"gg"}`` gprobe arrives pre-contracted by the backward."""
        one = jnp.float32(1.0)
        if isinstance(gprobe, dict):
            return eps * old_g + (one - eps) * gprobe["gg"] * float(n)
        cot = jax.lax.stop_gradient(gprobe)
        g_new = self._pallas_side(cot, old_g, (one - eps) * n, eps)
        if g_new is None:
            g_new = (eps * old_g
                     + (one - eps) * F.g_from_cotangent(gprobe, self.meta, n))
        return g_new

    def update_factors(self, old, rec, gprobe, batch, n, eps):
        if self.backend != "pallas" or self.lead:
            return super().update_factors(old, rec, gprobe, batch, n, eps)
        one = jnp.float32(1.0)
        # A side: fuse only when the raw activations were recorded (models
        # that contract Ā in-forward never materialize X outside the scan)
        a_new = self._pallas_side(rec.get("a"), old["a"], (one - eps) / n, eps)
        if a_new is None:
            a_c = (rec["aa"] / n if "aa" in rec else
                   F.outer_sum(rec["a"], "full", 1) / n)
            a_new = eps * old["a"] + (one - eps) * a_c
        return {"a": a_new, "g": self._g_side(old["g"], gprobe, n, eps)}

    # -- two-sided apply through the precond kernel ---------------------
    def precondition(self, inv, v):
        m = self.meta
        if (self.backend == "pallas" and tile_ok(m.a_dim, m.g_dim)
                and v.shape[-2:] == (m.a_dim, m.g_dim)):
            cfg = self._tuned("precond", (m.a_dim, m.g_dim), jnp.float32)
            fn = lambda a_i, vv, g_i: precond_kernel(
                a_i, vv, g_i, interpret=self._interpret(), **cfg)
            for _ in range(v.ndim - 2):      # vmap over stack/expert dims
                fn = jax.vmap(fn)
            return fn(inv["a_inv"], v.astype(jnp.float32), inv["g_inv"])
        return super().precondition(inv, v)

    # -- fused fixed-lr update chain through the update_chain kernel ----
    def precond_momentum(self, inv, v, mom, alpha, mu, eigen: bool = False):
        m = self.meta
        if (not eigen and self.backend == "pallas"
                and tile_ok(m.a_dim, m.g_dim)
                and v.shape == (m.a_dim, m.g_dim)):
            cfg = self._tuned("update_chain", (m.a_dim, m.g_dim),
                              jnp.float32)
            return chain_kernel(inv["a_inv"], v.astype(jnp.float32),
                                inv["g_inv"], mom, alpha=alpha, mu=mu,
                                interpret=self._interpret(), **cfg)
        return super().precond_momentum(inv, v, mom, alpha, mu, eigen)

    # -- eigenbasis apply through the rotate_rescale kernel -------------
    def precondition_eigen(self, eig, v):
        m = self.meta
        if (self.backend == "pallas" and tile_ok(m.a_dim, m.g_dim)
                and v.shape[-2:] == (m.a_dim, m.g_dim)):
            cfg = self._tuned("rotate_rescale", (m.a_dim, m.g_dim),
                              jnp.float32)
            fn = lambda qa, vv, qg, sd: rotate_rescale(
                qa, vv, qg, sd, lam=1e-12, interpret=self._interpret(),
                **cfg)
            for _ in range(v.ndim - 2):      # vmap over stack/expert dims
                fn = jax.vmap(fn)
            return fn(eig["qa"], v.astype(jnp.float32), eig["qg"],
                      eig["s"] + eig["damp"])
        return super().precondition_eigen(eig, v)
