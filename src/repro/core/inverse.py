"""Structured Fisher-block inverses + factored Tikhonov damping (S4.2, S6.3).

Damping (paper eqn. 7): each block's factors are damped as
``(Ā + π γ I) ⊗ (G + γ/π I)`` with the trace-norm choice
``π = sqrt( (tr Ā / d_A) / (tr G / d_G) )``.

Inversion methods:
  * ``eigh``  — exact symmetric eigendecomposition (fallback / reference)
  * ``ns``    — Newton–Schulz matmul-only iteration (MXU-native; the paper's
                own S8 pointer to Pan & Schreiber 1991), hot-startable from
                the previous inverse
  * ``solve`` — (used only in tests) dense jnp.linalg.inv

Eigenbasis (EKFAC) path — George et al., 1806.03884: instead of damped
factor *inverses*, :func:`eigen_pair_state` keeps the Kronecker
**eigenbases** ``Q_A, Q_G`` on the amortized T3 schedule plus a per-entry
diagonal in that basis.  The diagonal splits into ``s`` (second moments,
re-estimated every step from the rotated gradient — :func:`eigen_rescale`)
and ``damp`` (the factored-Tikhonov diagonal ``(γ/π)λ_A + πγλ_G + γ²``,
amortized with the bases), so right after a refresh
``s + damp = (λ_A + πγ)(λ_G + γ/π)`` and :func:`apply_eigen` reproduces the
``eigh`` inverse exactly, while between refreshes the scaling tracks the
live gradients at diagonal cost.

All routines are batched over arbitrary leading dims (layer stacks, experts,
TP blocks) — inverses of stacked factors are one batched kernel.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.tags import LayerMeta

_TINY = 1e-20


# ---------------------------------------------------------------------------
# traces / pi
# ---------------------------------------------------------------------------

def factor_trace(arr, kind: str):
    """Total trace per (stack/expert) index. Returns shape = lead dims."""
    if kind == "diag":
        return jnp.sum(arr, axis=-1)
    tr = jnp.trace(arr, axis1=-2, axis2=-1)
    if kind == "block":
        tr = jnp.sum(tr, axis=-1)          # sum over the block axis
    return tr


def pi_trace(a, a_kind, a_dim, g, g_kind, g_dim):
    """Paper S6.3 trace-norm pi, batched over lead dims."""
    a_tr = factor_trace(a, a_kind) / a_dim
    g_tr = factor_trace(g, g_kind) / g_dim
    return jnp.sqrt(jnp.maximum(a_tr, _TINY) / jnp.maximum(g_tr, _TINY))


# ---------------------------------------------------------------------------
# damped inverse of one factor
# ---------------------------------------------------------------------------

def _add_damp(arr, kind: str, damp):
    """damp has the lead-dims shape (no block axis)."""
    if kind == "diag":
        return arr + damp[..., None]
    d = arr.shape[-1]
    eye = jnp.eye(d, dtype=arr.dtype)
    if kind == "block":
        return arr + damp[..., None, None, None] * eye
    return arr + damp[..., None, None] * eye


def eigh_inverse(m, floor: float = 1e-12):
    w, v = jnp.linalg.eigh(m)
    wi = 1.0 / jnp.maximum(w, floor)
    return jnp.einsum("...ij,...j,...kj->...ik", v, wi, v)


def ns_inverse(m, iters: int, x0=None):
    """Newton–Schulz: X <- X (2I - M X).  m: (..., d, d) SPD (damped)."""
    d = m.shape[-1]
    eye = jnp.eye(d, dtype=m.dtype)
    lam = jnp.max(jnp.sum(jnp.abs(m), axis=-1), axis=-1)       # >= sigma_max
    cold = eye / lam[..., None, None]
    if x0 is None:
        x = cold
    else:
        # safeguard the hot start: ||I - M x0||_inf < 1 required
        r = eye - m @ x0
        bad = jnp.max(jnp.sum(jnp.abs(r), axis=-1), axis=-1) >= 1.0
        x = jnp.where(bad[..., None, None], cold, x0)

    def body(_, x):
        return x @ (2.0 * eye - m @ x)

    x = jax.lax.fori_loop(0, iters, body, x)
    return 0.5 * (x + jnp.swapaxes(x, -1, -2))


def factor_inverse(arr, kind: str, damp, *, method: str = "eigh",
                   iters: int = 12, prev=None):
    """Inverse of (factor + damp*I); diag kind returns the reciprocal."""
    arr = _add_damp(arr.astype(jnp.float32), kind, jnp.asarray(damp, jnp.float32))
    if kind == "diag":
        return 1.0 / jnp.maximum(arr, _TINY)
    if method == "eigh":
        return eigh_inverse(arr)
    if method == "ns":
        return ns_inverse(arr, iters, prev)
    return jnp.linalg.inv(arr)


def damped_pair_inverse(meta: LayerMeta, a, g, gamma, *, method="eigh",
                        iters=12, prev: Optional[Dict] = None):
    """Both inverses of one layer block under factored Tikhonov damping."""
    pi = pi_trace(a, meta.a_kind, meta.a_dim, g, meta.g_kind, meta.g_dim)
    a_inv = factor_inverse(a, meta.a_kind, pi * gamma, method=method,
                           iters=iters,
                           prev=None if prev is None else prev.get("a_inv"))
    g_inv = factor_inverse(g, meta.g_kind, gamma / pi, method=method,
                           iters=iters,
                           prev=None if prev is None else prev.get("g_inv"))
    return {"a_inv": a_inv, "g_inv": g_inv}


# ---------------------------------------------------------------------------
# eigenbasis (EKFAC) state:  F ≈ (Q_A ⊗ Q_G) diag(s + damp) (Q_A ⊗ Q_G)ᵀ
# ---------------------------------------------------------------------------

def eigh_basis(arr, kind: str):
    """Eigendecomposition of one factor: ``(q, w)``.

    ``q`` is the orthonormal eigenbasis (``None`` for diag factors — already
    in their eigenbasis, so rotation is the identity); ``w`` the eigenvalues
    flattened to ``(*lead, dim)`` (block factors concatenate their per-block
    spectra, matching the flat layout :func:`apply_eigen` rotates into).
    """
    if kind == "diag":
        return None, jnp.maximum(arr, 0.0)
    w, q = jnp.linalg.eigh(arr)
    if kind == "block":
        w = w.reshape(*w.shape[:-2], -1)
    return q, jnp.maximum(w, 0.0)          # clip eigh's tiny negatives (PSD)


def _rot_left(q, kind: str, v, adjoint: bool):
    """Rotate along d_in: ``Qᵀ v`` (adjoint) or ``Q v``; None = identity."""
    if q is None:
        return v
    return _mul_left(jnp.swapaxes(q, -1, -2) if adjoint else q, kind, v)


def _rot_right(q, kind: str, v, adjoint: bool):
    """Rotate along d_out: ``v Q`` (adjoint) or ``v Qᵀ``; None = identity."""
    if q is None:
        return v
    return _mul_right(q if adjoint else jnp.swapaxes(q, -1, -2), kind, v)


def rotate_eigen(meta: LayerMeta, qa, qg, v, *, adjoint: bool):
    """``Q_Aᵀ V Q_G`` (adjoint=True: into the eigenbasis) or ``Q_A V Q_Gᵀ``."""
    u = _rot_left(qa, meta.a_kind, v, adjoint)
    return _rot_right(qg, meta.g_kind, u, adjoint)


def _eigen_parts(meta: LayerMeta, a, g):
    """The gamma-independent pieces: bases, eigenvalue column/row, pi."""
    qa, wa = eigh_basis(a, meta.a_kind)
    qg, wg = eigh_basis(g, meta.g_kind)
    pi = pi_trace(a, meta.a_kind, meta.a_dim, g, meta.g_kind, meta.g_dim)
    return qa, qg, wa[..., :, None], wg[..., None, :], pi


def _eigen_damp(wa_col, wg_row, pi, gamma):
    """Factored-Tikhonov diagonal ``(γ/π)λ_A + πγλ_G + γ²`` (broadcastable)."""
    gamma = jnp.asarray(gamma, jnp.float32)
    return ((gamma / pi)[..., None, None] * wa_col
            + (pi * gamma)[..., None, None] * wg_row + jnp.square(gamma))


def eigen_pair_state(meta: LayerMeta, a, g, gamma):
    """Amortized EKFAC state of one block: bases + eigenbasis diagonals.

    Returns ``{"qa", "qg", "s", "damp"}`` where ``s`` is initialized to the
    Kronecker eigenvalue products ``λ_A,i λ_G,j`` (the exact Fisher diagonal
    in this basis) and ``damp`` carries the factored-Tikhonov cross terms, so
    dividing by ``s + damp`` equals the ``eigh`` factor-inverse apply until
    :func:`eigen_rescale` starts re-estimating ``s`` from live gradients.
    """
    qa, qg, wa_col, wg_row, pi = _eigen_parts(meta, a, g)
    s = wa_col * wg_row
    damp = jnp.broadcast_to(_eigen_damp(wa_col, wg_row, pi, gamma), s.shape)
    return {"qa": qa, "qg": qg, "s": s, "damp": damp}


def eigen_pair_multi(meta: LayerMeta, a, g, gammas):
    """Candidate-stacked eigen states for the S6.6 gamma sweep, sharing ONE
    eigendecomposition per factor — only ``damp`` depends on gamma, so the
    bases/diagonals are broadcast across the leading candidate axis instead
    of recomputed per candidate."""
    qa, qg, wa_col, wg_row, pi = _eigen_parts(meta, a, g)
    s = wa_col * wg_row
    damp = jax.vmap(lambda gm: jnp.broadcast_to(
        _eigen_damp(wa_col, wg_row, pi, gm), s.shape))(gammas)
    n = gammas.shape[0]
    tile = lambda x: (None if x is None
                      else jnp.broadcast_to(x[None], (n, *x.shape)))
    return {"qa": tile(qa), "qg": tile(qg), "s": tile(s), "damp": damp}


def eigen_rescale(meta: LayerMeta, eig, grad, eps):
    """Per-step EKFAC diagonal update: ``s ← εs + (1−ε)(Q_Aᵀ ∇ Q_G)²``."""
    t = rotate_eigen(meta, eig["qa"], eig["qg"],
                     grad.astype(jnp.float32), adjoint=True)
    return dict(eig, s=eps * eig["s"] + (1.0 - eps) * jnp.square(t))


def apply_eigen(meta: LayerMeta, eig, v, floor: float = 1e-12):
    """``U = Q_A [ (Q_Aᵀ V Q_G) / (s + damp) ] Q_Gᵀ``; v shaped like W."""
    t = rotate_eigen(meta, eig["qa"], eig["qg"],
                     v.astype(jnp.float32), adjoint=True)
    t = t / (eig["s"] + eig["damp"] + floor)
    return rotate_eigen(meta, eig["qa"], eig["qg"], t, adjoint=False)


# ---------------------------------------------------------------------------
# preconditioning:  U = Ā⁻¹ V G⁻¹   (V stored (d_in[, +1], d_out) like W)
# ---------------------------------------------------------------------------

def _mul_left(inv, kind: str, v):
    """Multiply along the d_in (second-to-last) axis of v."""
    if kind == "diag":
        return v * inv[..., :, None]
    if kind == "block":
        nb, db = inv.shape[-3], inv.shape[-1]
        lead = v.shape[:-2]
        vr = v.reshape(*lead, nb, db, v.shape[-1])
        out = jnp.einsum("...nij,...njk->...nik", inv, vr)
        return out.reshape(*lead, nb * db, v.shape[-1])
    return jnp.einsum("...ij,...jk->...ik", inv, v)


def _mul_right(inv, kind: str, v):
    """Multiply along the d_out (last) axis of v."""
    if kind == "diag":
        return v * inv[..., None, :]
    if kind == "block":
        nb, db = inv.shape[-3], inv.shape[-1]
        vr = v.reshape(*v.shape[:-1], nb, db)        # (..., d_in, nb, db)
        out = jnp.einsum("...inj,...njk->...ink", vr, inv)
        return out.reshape(*v.shape)
    return jnp.einsum("...ij,...jk->...ik", v, inv)


def apply_block_inverse(meta: LayerMeta, inv: Dict, v):
    """U = Ā⁻¹ V G⁻¹ with per-kind structure; v shaped like the weight."""
    u = _mul_left(inv["a_inv"], meta.a_kind, v.astype(jnp.float32))
    return _mul_right(inv["g_inv"], meta.g_kind, u)
