"""Block-tridiagonal inverse approximation F̂⁻¹ = Ξᵀ Λ Ξ (paper S4.3, App B).

Defined for *chain* models (the paper's MLPs — see DESIGN §Arch-applicability
for why the transformer DAG uses the block-diagonal approximation instead).

Needs cross moments between consecutive layers:
  Ā_{i,i+1} = E[ā_i ā_{i+1}ᵀ]   (inputs of consecutive tagged layers)
  G_{i,i+1} = E[g_i g_{i+1}ᵀ]

and per-layer damped diagonal factors.  Matrix layout note: the Fisher block
acts on vec(DW) with DW = g āᵀ of shape (d_out, d_in+1); internally we work
in that layout and transpose to/from the (d_in+1, d_out) weight layout.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.inverse import eigh_inverse, pi_trace

_EPS = 1e-8


def _inv_sqrt(m, floor=1e-10, polish: int = 2):
    """Symmetric inverse square root M^{-1/2}.

    The f32 eigh seed alone leaves a ~cond(M)·eps residual that the App-B
    Σ⁻¹ identity amplifies past usable tolerance, so the seed is polished
    with Newton–Schulz steps Y ← ½ Y (3I − M Y²) (quadratic convergence:
    each step squares the relative residual).  The polish iterates against
    M itself, which diverges explosively on eigenvalues below the clamp
    floor (roundoff-indefinite factors), so it is kept only when M's
    spectrum is safely positive — otherwise the clamped seed stands.
    """
    w, v = jnp.linalg.eigh(m)
    wi = jax.lax.rsqrt(jnp.maximum(w, floor))
    y0 = jnp.einsum("ij,j,kj->ik", v, wi, v)
    eye = jnp.eye(m.shape[-1], dtype=y0.dtype)
    y = y0
    for _ in range(polish):
        y = 0.5 * y @ (3.0 * eye - (m @ y) @ y)
        y = 0.5 * (y + y.T)
    ok = w[..., 0] > floor        # eigh sorts ascending: min eigenvalue
    return jnp.where(ok, y, y0)


# ---------------------------------------------------------------------------
# statistics
# ---------------------------------------------------------------------------

def init_cross_state(model) -> Dict[str, jnp.ndarray]:
    order = model.layer_order
    metas = model.metas
    out = {}
    for i in range(len(order) - 1):
        mi, mj = metas[order[i]], metas[order[i + 1]]
        out[f"a{i}"] = jnp.zeros((mi.a_dim, mj.a_dim), jnp.float32)
        out[f"g{i}"] = jnp.zeros((mi.g_dim, mj.g_dim), jnp.float32)
    return out


def cross_contrib(model, recs, gprobes, n: int) -> Dict[str, jnp.ndarray]:
    order = model.layer_order
    out = {}
    for i in range(len(order) - 1):
        ai = recs[order[i]]["a"].astype(jnp.float32)
        aj = recs[order[i + 1]]["a"].astype(jnp.float32)
        out[f"a{i}"] = jnp.einsum("ni,nj->ij", ai, aj) / n
        gi = jax.lax.stop_gradient(gprobes[order[i]]).astype(jnp.float32)
        gj = jax.lax.stop_gradient(gprobes[order[i + 1]]).astype(jnp.float32)
        # per-token g = n * cot  =>  E[g_i g_jᵀ] = n Σ cot_i cot_jᵀ
        out[f"g{i}"] = jnp.einsum("ni,nj->ij", gi, gj) * n
    return out


# ---------------------------------------------------------------------------
# inverse precomputation (every T3 steps)
# ---------------------------------------------------------------------------

def precompute(model, factors, gamma, eta) -> Dict:
    """Damped Ψ / Σ cached quantities (paper S4.3 with S6.3 damping)."""
    order = model.layer_order
    metas = model.metas
    ell = len(order)
    cross = factors["__cross__"]

    a_d, g_d = [], []
    for name in order:
        m = metas[name]
        a = factors[name]["a"].astype(jnp.float32)
        g = factors[name]["g"].astype(jnp.float32)
        pi = pi_trace(a, m.a_kind, m.a_dim, g, m.g_kind, m.g_dim)
        a_d.append(a + (pi * gamma) * jnp.eye(a.shape[-1]))
        g_d.append(g + (gamma / pi) * jnp.eye(g.shape[-1]))

    psi_a, psi_g, appb = [], [], []
    for i in range(ell - 1):
        a_cross = cross[f"a{i}"]
        g_cross = cross[f"g{i}"]
        pa = a_cross @ eigh_inverse(a_d[i + 1])          # Ψ^Ā_{i,i+1}
        pg = g_cross @ eigh_inverse(g_d[i + 1])          # Ψ^G_{i,i+1}
        psi_a.append(pa)
        psi_g.append(pg)
        # Σ_{i|i+1} = A_i ⊗ B_i − C ⊗ D  (A-side=Ā, B-side=G)
        a_mat = a_d[i]
        b_mat = g_d[i]
        c_mat = pa @ a_d[i + 1] @ pa.T
        d_mat = pg @ g_d[i + 1] @ pg.T
        a_is = _inv_sqrt(a_mat)
        b_is = _inv_sqrt(b_mat)
        s1, e1 = jnp.linalg.eigh(a_is @ c_mat @ a_is)
        s2, e2 = jnp.linalg.eigh(b_is @ d_mat @ b_is)
        appb.append({"k1": a_is @ e1, "k2": b_is @ e2,
                     "s1": s1, "s2": s2})
    last = {"a_inv": eigh_inverse(a_d[-1]), "g_inv": eigh_inverse(g_d[-1])}
    return {"psi_a": psi_a, "psi_g": psi_g, "appb": appb, "last": last}


# ---------------------------------------------------------------------------
# application: U = F̂⁻¹ V  (paper S4.3)
# ---------------------------------------------------------------------------

def _sigma_inv_apply(cache, x):
    """(A⊗B − C⊗D)⁻¹ vec(X) per Appendix B; X in (B-side, A-side) layout."""
    k1, k2, s1, s2 = cache["k1"], cache["k2"], cache["s1"], cache["s2"]
    inner = k2.T @ x @ k1
    denom = 1.0 - s2[:, None] * s1[None, :]
    denom = jnp.where(jnp.abs(denom) < _EPS, _EPS, denom)
    return k2 @ (inner / denom) @ k1.T


def apply(model, tri, vs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    order = model.layer_order
    ell = len(order)
    # to Fisher layout: X_i = V_iᵀ  (d_out, d_in+1)
    xs = [vs[name].astype(jnp.float32).T for name in order]

    # u = Ξ v   (U_i = X_i − Ψ^G_i X_{i+1} Ψ^Āᵢᵀ ; U_{ℓ-1} = X_{ℓ-1})
    us = list(xs)
    for i in range(ell - 1):
        us[i] = xs[i] - tri["psi_g"][i] @ xs[i + 1] @ tri["psi_a"][i].T

    # y = Λ u
    ys = []
    for i in range(ell - 1):
        ys.append(_sigma_inv_apply(tri["appb"][i], us[i]))
    ys.append(tri["last"]["g_inv"] @ us[-1] @ tri["last"]["a_inv"])

    # z = Ξᵀ y  (Z_i = Y_i − Ψ^G_{i-1}ᵀ Y_{i-1} Ψ^Ā_{i-1} ; Z_0 = Y_0)
    zs = list(ys)
    for i in range(1, ell):
        zs[i] = ys[i] - tri["psi_g"][i - 1].T @ ys[i - 1] @ tri["psi_a"][i - 1]

    return {name: zs[i].T for i, name in enumerate(order)}
