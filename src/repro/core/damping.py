"""Adaptive damping rules (paper S6.5, S6.6)."""
from __future__ import annotations

import jax.numpy as jnp

LAM_MIN, LAM_MAX = 1e-8, 1e8
GAMMA_MIN, GAMMA_MAX = 1e-6, 1e4


def lambda_update(lam, rho, omega1: float):
    """Levenberg–Marquardt rule: shrink when the quadratic model predicts
    well (rho > 3/4), grow when it doesn't (rho < 1/4)."""
    lam = jnp.where(rho > 0.75, lam * omega1, lam)
    lam = jnp.where(rho < 0.25, lam / omega1, lam)
    return jnp.clip(lam, LAM_MIN, LAM_MAX)


def gamma_candidates(gamma, omega2: float):
    """The greedy T2-periodic sweep: {γ, ω γ, γ/ω}."""
    return jnp.stack([gamma, jnp.clip(gamma * omega2, GAMMA_MIN, GAMMA_MAX),
                      jnp.clip(gamma / omega2, GAMMA_MIN, GAMMA_MAX)])
