"""Curvature tagging: how models expose per-layer (ā, g) pairs to K-FAC.

The paper needs, for every layer ``s = ā W``, the input activations ``ā`` and
the pre-activation gradients ``g = dL/ds`` **per example** (S3, S5).  In JAX we
get per-example g's with the *zero-probe* trick: the forward computes
``s = ā W + p`` where ``p`` is an all-zeros array shaped like ``s`` that is an
explicit argument of the differentiated function.  ``grad`` w.r.t. ``p`` is
exactly ``dL/ds`` with per-example resolution, and it rides the same backward
pass that produces the parameter gradients.

Layers are described by :class:`LayerMeta`; models return a dict of recorded
activations via the :class:`Tagger` threaded through their forward pass.

Scan-stacked layers (the transformer blocks are `lax.scan`-ed over stacked
parameters) record activations as scan outputs, so every recorded array (and
every probe) carries a leading ``n_stack`` dimension.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax


@dataclass(frozen=True)
class LayerMeta:
    """Static description of one K-FAC-tagged linear map."""

    name: str
    param_path: Tuple[Any, ...]     # path into the params pytree -> weight
    d_in: int
    d_out: int
    kind: str = "dense"             # dense | expert | embed
    n_stack: int = 0                # >0: leading scan-stack dim on weight/factors
    n_expert: int = 0               # >0: per-expert factors (kind == "expert")
    a_kind: str = "full"            # full | diag | block
    g_kind: str = "full"            # full | diag | block
    a_blocks: int = 1               # block count when a_kind == "block"
    g_blocks: int = 1
    has_bias: bool = False          # homogeneous coordinate appended to ā
    probe_tshard: bool = False      # context-parallel outputs: probe shards
                                    # the sequence dim (not the feature dim)
    # convolution layers (kind == "conv", KFC — Grosse & Martens 1602.01407):
    # the weight is stored as a (prod(conv_spatial)*conv_in [+1], d_out)
    # matrix whose rows are tap-major patch features [k, c]; d_in is the
    # flattened patch width prod(conv_spatial) * conv_in.
    conv_spatial: Tuple[int, ...] = ()   # kernel spatial shape (K,) / (Kh, Kw)
    conv_stride: Tuple[int, ...] = ()    # window strides, same rank
    conv_in: int = 0                     # input channels C
    conv_pad: str = "VALID"              # lax conv padding ("SAME" | "VALID")

    @property
    def a_dim(self) -> int:
        return self.d_in + (1 if self.has_bias else 0)

    @property
    def g_dim(self) -> int:
        return self.d_out


class Tagger:
    """Forward-pass context. Modes:

    * ``plain``   — inference; tags are no-ops.
    * ``shapes``  — record the pre-activation arrays themselves (used under
      ``jax.eval_shape`` to discover probe shapes; never executed for real).
    * ``collect`` — add probes, record activations (the stats pass).
    """

    def __init__(self, mode: str = "plain", probes: Optional[Dict[str, Any]] = None,
                 contract: Optional[Dict[str, Any]] = None,
                 gcontract: Optional[Dict[str, Any]] = None):
        assert mode in ("plain", "shapes", "collect")
        self.mode = mode
        self.probes = probes or {}
        # name -> callable(a) -> contracted A-side outer-product sum; when a
        # tag has an entry, only the (tiny) contraction is recorded instead of
        # the raw activations.
        self.contract = contract or {}
        # name -> callable(ds) -> contracted G-side outer-product sum, used
        # when the layer's probe is the fused ``{"gg": ...}`` form (see
        # repro.core.fused): the contraction rides the backward pass as the
        # probe's custom-VJP cotangent instead of a raw (N, d_out) array.
        self.gcontract = gcontract or {}
        self.records: Dict[str, Any] = {}

    def _add_probe(self, name: str, s):
        """Add the layer's zero probe to ``s`` — or, for a fused ``{"gg"}``
        probe, attach the custom-VJP that contracts the probe cotangent in
        the backward pass itself."""
        if name not in self.probes:
            return s
        p = self.probes[name]
        if isinstance(p, dict):
            from repro.core import fused
            fn = self.gcontract.get(name, fused.einsum_gg)
            return fused.apply_gprobe(s, p["gg"], fn)
        return s + p

    def tag(self, name: str, a, s, weight=None):
        """Tag a dense map: ``a`` inputs (..., d_in), ``s`` outputs (..., d_out).

        ``weight``: optional per-position weights (MoE slot mask) with shape
        ``s.shape[:-1]``. Returns ``s`` (plus probe in collect mode).
        """
        if self.mode == "plain":
            return s
        if self.mode == "shapes":
            self.records[name] = s
            return s
        # collect
        fn = self.contract.get(name)
        a_sg = jax.lax.stop_gradient(a)
        rec = {"aa": fn(a_sg)} if fn is not None else {"a": a_sg}
        self.records[name] = rec
        return self._add_probe(name, s)

    def tag_conv(self, name: str, x, s):
        """Tag a convolution: ``x`` the RAW (pre-im2col) input
        ``(B, *spatial, C)``, ``s`` the outputs ``(B, T_out, d_out)`` with the
        spatial dims flattened.  Only the raw input is recorded — the
        ``ConvKronecker`` block extracts patches itself (possibly fused into
        the Pallas factor kernel), so collect mode never materializes the
        im2col buffer in the record."""
        if self.mode == "plain":
            return s
        if self.mode == "shapes":
            self.records[name] = s
            return s
        fn = self.contract.get(name)
        x_sg = jax.lax.stop_gradient(x)
        self.records[name] = ({"aa": fn(x_sg)} if fn is not None
                              else {"cx": x_sg})
        return self._add_probe(name, s)

    def tag_embed(self, name: str, ids, s):
        """Tag an embedding lookup: ``ids`` int tokens, ``s`` embeddings."""
        if self.mode == "plain":
            return s
        if self.mode == "shapes":
            self.records[name] = s
            return s
        self.records[name] = {"ids": ids}
        if name in self.probes:
            s = s + self.probes[name]
        return s

    def out(self) -> Dict[str, Any]:
        """Records to be returned (e.g. as scan ys)."""
        return self.records


def merge_records(*records: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for r in records:
        for k, v in r.items():
            if k in out:
                raise ValueError(f"duplicate K-FAC tag {k!r}")
            out[k] = v
    return out
