"""Functional optimizer API: optax-style gradient-transformation pipeline.

The paper frames K-FAC as "the plain stochastic gradient plus a
preconditioner"; this module supplies the frame itself.  Two protocols:

``Transform(init, update)``
    A *pure* gradient transformation, exactly optax's contract::

        state            = tx.init(params)
        updates, state   = tx.update(updates, state, params)

    Transforms compose with :func:`chain`.  The generic building blocks
    (:func:`scale`, :func:`with_momentum`, :func:`scale_by_adam`,
    :func:`add_decayed_weights`, :func:`clip_by_global_norm`) are enough to
    express the paper's own baselines — SGD with momentum and Adam — in the
    same API the K-FAC pipeline speaks.

``Optimizer(init, update, reject, ...)``
    The full trainer-facing object::

        state = opt.init(params, batch)
        new_params, state, metrics = opt.update(grads, state, params,
                                                batch, rng)

    ``grads`` may be ``None``, in which case the optimizer runs its own
    gradient pass (K-FAC *must* be driven this way: its gradient and
    statistics passes share one forward, see
    :mod:`repro.optimizers.kfac`).  ``reject(state)`` is the non-finite
    -update hook the trainer calls instead of applying a poisoned step
    (K-FAC raises damping and clears momentum; first-order methods are a
    no-op).  ``Trainer.fit`` calls nothing but ``init`` / ``update`` /
    ``reject`` plus the checkpoint hooks — it contains no
    optimizer-specific branches.

The optimizer *state* is typed: :class:`KFACState` (the K-FAC pipeline) and
:class:`TransformState` (first-order baselines) are frozen dataclasses
registered with :func:`jax.tree_util.register_dataclass`, so they jit,
shard (``Optimizer.state_shardings``), ``eval_shape`` and checkpoint as
ordinary pytrees — no string-key plumbing.  Field names deliberately match
the historical dict keys so pre-dataclass checkpoints restore unchanged
(see ``training/checkpoint.py``'s schema note), and ``__getitem__`` keeps
``state["lam"]``-style legacy reads working.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils import tree as T


# ---------------------------------------------------------------------------
# typed optimizer states
# ---------------------------------------------------------------------------

def _register(cls, data_fields):
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=[])
    return cls


@dataclasses.dataclass(frozen=True)
class KFACState:
    """K-FAC optimizer state (paper Algorithm 2), one field per concern.

    ``factors``  per-block running Kronecker factors {"a", "g"} (S5);
    ``inv``      per-block damped inverses — or, in ``inv_mode="eigen"``,
                 the EKFAC eigen state {"qa", "qg", "s", "damp"};
    ``diag``     diagonal curvature for untagged (elementwise) params;
    ``delta0``   previous update (the S7 momentum tangent);
    ``lam`` / ``gamma``  LM damping (S6.5) and factored damping (S6.6);
    ``m_delta`` / ``loss_prev``  quadratic-model value and last loss, the
                 inputs to the rho reduction ratio;
    ``staleness``  steps the in-flight asynchronous refresh has been
                 pending (``refresh_mode="overlap"``; bounded by T3 —
                 the controller blocks and swaps at the ceiling).  Stays
                 0 in the synchronous refresh modes;
    ``inv_pending``  the overlap mode's second inverse buffer (same
                 structure as ``inv``; the async swap target) — ``None``
                 in the other refresh modes, so they pay no extra state.

    Field names match the historical dict-state keys — the checkpoint
    migration shim depends on this (old dict checkpoints restore by key;
    the v3 fields ``staleness``/``inv_pending`` fall back to template
    values when restoring schema<=2 checkpoints, see
    ``training/checkpoint.py``).
    """

    step: jax.Array
    k_stats: jax.Array
    lam: jax.Array
    gamma: jax.Array
    factors: Any
    inv: Any
    diag: Any
    delta0: Any
    m_delta: jax.Array
    loss_prev: jax.Array
    staleness: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))
    inv_pending: Any = None

    def replace(self, **kw) -> "KFACState":
        return dataclasses.replace(self, **kw)

    def __getitem__(self, key: str):
        """Legacy dict-style read (``state["lam"]``)."""
        return getattr(self, key)


_register(KFACState, [f.name for f in dataclasses.fields(KFACState)])


@dataclasses.dataclass(frozen=True)
class TransformState:
    """State of a first-order :class:`Optimizer` built from a Transform:
    the step counter plus the chained transform's own state tuple."""

    step: jax.Array
    inner: Any

    def replace(self, **kw) -> "TransformState":
        return dataclasses.replace(self, **kw)

    def __getitem__(self, key: str):
        return getattr(self, key)


_register(TransformState, ["step", "inner"])


# ---------------------------------------------------------------------------
# the two protocols
# ---------------------------------------------------------------------------

class Transform(NamedTuple):
    """Pure gradient transformation: ``init(params)``,
    ``update(updates, state, params) -> (updates, state)``."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Trainer-facing optimizer bundle (not a pytree — plain callables).

    ``update(grads, state, params, batch, rng)`` returns
    ``(new_params, state, metrics)``; ``grads=None`` asks the optimizer to
    run its own gradient pass.  ``poll(state) -> state``, when set, is the
    trainer's end-of-step swap hook: optimizers running asynchronous side
    computations (K-FAC's ``refresh_mode="overlap"`` double-buffered
    inverse refresh) commit any finished buffer here without blocking.
    ``engine`` exposes the optimizer-specific stage engine (the K-FAC
    pipeline publishes its jit-able stages there for lowering / dry-run
    use); ``transform`` the underlying pure Transform for first-order
    methods.
    """

    init: Callable[[Any, Any], Any]
    update: Callable[..., tuple]
    reject: Callable[[Any], Any] = lambda state: state
    state_shardings: Optional[Callable] = None
    poll: Optional[Callable[[Any], Any]] = None
    engine: Any = None
    transform: Optional[Transform] = None
    name: str = "optimizer"


# ---------------------------------------------------------------------------
# generic transforms (the paper's first-order baselines live on these)
# ---------------------------------------------------------------------------

def chain(*transforms: Transform) -> Transform:
    """Compose transforms left-to-right over the update pytree."""

    def init(params):
        return tuple(tx.init(params) for tx in transforms)

    def update(updates, state, params):
        new_state = []
        for tx, s in zip(transforms, state):
            updates, s = tx.update(updates, s, params)
            new_state.append(s)
        return updates, tuple(new_state)

    return Transform(init, update)


def identity() -> Transform:
    return Transform(lambda params: (),
                     lambda u, s, p: (u, s))


def scale(factor: float) -> Transform:
    """``u <- factor * u`` (e.g. ``scale(-lr)``)."""
    return Transform(lambda params: (),
                     lambda u, s, p: (T.tree_scale(u, factor), s))


def add_decayed_weights(weight_decay: float) -> Transform:
    """``u <- u + wd * p``.  Placed before the momentum/Adam rescaling this
    is classical L2 regularization; placed after it (as the adam chain
    does), decoupled AdamW-style decay."""
    return Transform(
        lambda params: (),
        lambda u, s, p: (jax.tree.map(
            lambda ui, pi: ui + weight_decay * pi.astype(ui.dtype), u, p), s))


def clip_by_global_norm(max_norm: float) -> Transform:
    """Rescale ``u`` so its global l2 norm is at most ``max_norm``."""

    def update(u, s, p):
        gn = jnp.sqrt(T.tree_sqnorm(u))
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-20))
        return T.tree_scale(u, factor), s

    return Transform(lambda params: (), update)


def momentum_global_clip(momentum: float, max_norm: float) -> Transform:
    """Fused ``chain(with_momentum(momentum), clip_by_global_norm(max_norm))``
    as one traversal: the velocity update, its global norm, and the clip
    rescale come out of a single pass over the update tree — the pipeline
    stage the fused ``update_chain`` kernel serves inside ``KFACEngine``
    (``KFACConfig.fixed_momentum`` / ``clip_delta_norm``).  State is the
    velocity alone; the clip is stateless and applies to the emitted value
    only (the stored velocity stays un-clipped, like the chained form)."""

    def init(params):
        return T.tree_zeros_like(params)

    def update(u, vel, p):
        vel = jax.tree.map(lambda v, ui: momentum * v + ui, vel, u)
        gn = jnp.sqrt(T.tree_sqnorm(vel))
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-20))
        return T.tree_scale(vel, factor), vel

    return Transform(init, update)


def with_kl_clip(inner: Transform, max_kl: float, lr: float = 1.0) -> Transform:
    """Norm-constraint ("KL clip") wrapper — the knob every production
    K-FAC ships (kfac_jax ``norm_constraint``, pytorch-kfac ``kl_clip``).

    The trust region is the Fisher quadratic of the *applied* step: with
    preconditioned direction ``Δ = inner(g)``, the second-order KL change
    of step ``lr·Δ`` is ``≈ ½ lr² ΔᵀFΔ ≈ ½ lr² |Δᵀg|`` (using ``FΔ ≈ g``
    when Δ is the damped-inverse apply of g).  The emitted update is

        ν · inner(g),   ν = min(1, sqrt(max_kl / (lr² · |Δᵀg|)))

    so the step never moves the predictive distribution by more than
    ``max_kl`` nats (to second order).  The raw incoming update is
    remembered as the gradient proxy *before* ``inner`` runs, which is why
    this is a wrapper and not a chain stage.  Inner state is passed
    through untouched (the stored velocity stays un-scaled, matching
    ``momentum_global_clip``'s convention)."""

    def init(params):
        return inner.init(params)

    def update(u, s, p):
        g = u
        u2, s = inner.update(u, s, p)
        quad = jnp.abs(T.tree_dot(u2, g))
        nu = jnp.minimum(
            1.0, jnp.sqrt(max_kl / jnp.maximum(lr * lr * quad, 1e-20)))
        return T.tree_scale(u2, nu), s

    return Transform(init, update)


def with_momentum(momentum: float) -> Transform:
    """Heavy-ball velocity: ``v <- momentum * v + u``; emits ``v``.

    Placed *after* ``scale(-lr)`` this is exactly the classical
    ``v <- m v - lr g; p <- p + v`` recursion the paper tunes SGD with.
    """

    def init(params):
        return T.tree_zeros_like(params)

    def update(u, vel, p):
        vel = jax.tree.map(lambda v, ui: momentum * v + ui, vel, u)
        return vel, vel

    return Transform(init, update)


def scale_by_adam(b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-8) -> Transform:
    """Adam's bias-corrected first/second-moment rescaling (sans -lr)."""

    def init(params):
        return {"mu": T.tree_zeros_like(params),
                "nu": T.tree_zeros_like(params),
                "count": jnp.int32(0)}

    def update(u, s, p):
        count = s["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, s["mu"], u)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, s["nu"], u)
        c = count.astype(jnp.float32)
        bc1 = 1.0 - jnp.power(jnp.float32(b1), c)
        bc2 = 1.0 - jnp.power(jnp.float32(b2), c)
        out = jax.tree.map(
            lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu)
        return out, {"mu": mu, "nu": nu, "count": count}

    return Transform(init, update)


# ---------------------------------------------------------------------------
# Transform -> Optimizer
# ---------------------------------------------------------------------------

def apply_updates(params, updates):
    """``p <- p + u`` in the parameter dtype."""
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def model_value_and_grad(model):
    """Generic gradient pass over the repo's model protocol
    (``model.loss(params, None, batch, rng, mode="plain")``)."""

    def f(params, batch, rng):
        def f1(p):
            (lt, _), aux = model.loss(p, None, batch, rng, mode="plain")
            return lt, aux["metrics"]

        (_, metrics), grads = jax.value_and_grad(f1, has_aux=True)(params)
        return grads, dict(metrics)

    return f


def from_transform(transform: Transform, model=None,
                   name: str = "transform") -> Optimizer:
    """Lift a pure Transform into a trainer-facing :class:`Optimizer`.

    With ``model`` given, ``update(None, state, params, batch, rng)`` runs
    one jitted step (gradient pass + transform + apply).  Without a model,
    callers must pass ``grads`` explicitly (pure optax-style use)."""
    gradfn = model_value_and_grad(model) if model is not None else None

    def init(params, batch=None):
        return TransformState(step=jnp.int32(0),
                              inner=transform.init(params))

    @jax.jit
    def _apply(grads, state, params):
        updates, inner = transform.update(grads, state.inner, params)
        new_params = apply_updates(params, updates)
        metrics = {"grad_norm": jnp.sqrt(T.tree_sqnorm(grads)),
                   "delta_norm": jnp.sqrt(T.tree_sqnorm(updates))}
        return new_params, TransformState(state.step + 1, inner), metrics

    @jax.jit
    def _step(state, params, batch, rng):
        grads, metrics = gradfn(params, batch, rng)
        new_params, state, m2 = _apply(grads, state, params)
        return new_params, state, {**metrics, **m2}

    def update(grads, state, params, batch=None, rng=None):
        if grads is None:
            if gradfn is None:
                raise ValueError(
                    f"{name}: no model bound — pass explicit grads")
            return _step(state, params, batch, rng)
        return _apply(grads, state, params)

    return Optimizer(init=init, update=update, transform=transform,
                     name=name)
