"""Backward-pass fusion of the factor statistics (paper S5, one pass).

The two-pass layout records raw activations in the forward and raw probe
cotangents out of the backward, then makes a *second* sweep over both to
form ``Ā += ā āᵀ`` / ``G += g gᵀ`` — every recorded ``(N, d)`` tensor is
written to HBM by the stats pass and read back by ``update_factors``.  With
``KFACConfig.fused_stats`` the contractions ride the passes themselves:

  * **A side** — the ``Tagger`` contract hook (the mechanism the scan models
    already use) records ``{"aa": Σ ā āᵀ}`` in-forward;
    :func:`dense_a_contract` / :func:`conv_a_contract` build the per-layer
    contraction, routing through the Pallas ``factor_update`` /
    ``patch_factor`` kernels when shapes tile.
  * **G side** — :func:`apply_gprobe`, a custom-VJP identity whose backward
    emits ``{"gg": Σ cot cotᵀ}`` as the probe's cotangent: the per-example
    ``dL/ds`` is contracted the moment the VJP produces it, while it is
    still live, instead of being materialized as an ``(N, d_out)`` probe
    cotangent and re-read.

Blocks see ``{"aa": ...}`` records and ``{"gg": ...}`` gprobes and skip
straight to the decayed blend — numerically the same contraction (same
einsum / same kernel) over the same values, so fused runs sit inside the
golden envelopes (``tests/test_autotune.py`` pins allclose per inv_mode).

Eligibility (enforced by :func:`fused_eligible`, wired in ``KFACEngine``):
dense/conv layers with full/full factors and no stack/expert lead dims.
``inv_mode="tridiag"`` disables fusion entirely — the chain's cross moments
need the raw per-layer records.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tags import LayerMeta
from repro.kernels.autotune import tuned
from repro.kernels.compat import tile_ok
from repro.kernels.factor_update import factor_update


def fused_eligible(meta: LayerMeta) -> bool:
    """Layers whose stats can contract in-pass: plain dense/conv maps with
    full two-sided factors and no scan-stack / expert lead dims (stacked
    layers record through inner scan Taggers; their probes carry lead dims
    the per-layer contraction cannot see)."""
    return (meta.kind in ("dense", "conv") and meta.n_stack == 0
            and meta.n_expert == 0 and meta.a_kind == "full"
            and meta.g_kind == "full")


def _xtx(x2, backend: str, interpret: bool, mode: str):
    """``Σ xᵀx`` over rows — the Pallas rank-update kernel when the shape
    tiles, else the same f32-accumulated einsum ``F.outer_sum`` uses (so the
    xla fused path is bitwise the unfused contraction)."""
    if backend == "pallas" and tile_ok(*x2.shape):
        cfg = tuned("factor_update", x2.shape, x2.dtype,
                    interpret=interpret, mode=mode) or {}
        zero = jnp.zeros((x2.shape[1], x2.shape[1]), jnp.float32)
        return factor_update(x2, zero, alpha=1.0, beta=0.0,
                             interpret=interpret, **cfg)
    return jnp.einsum("nd,ne->de", x2, x2,
                      preferred_element_type=jnp.float32)


def dense_a_contract(meta: LayerMeta, backend: str, interpret: bool,
                     mode: str):
    """In-forward Ā contraction for a dense layer: ``ā`` (..., a_dim) →
    ``Σ ā āᵀ`` (a_dim, a_dim), recorded as ``{"aa": ...}``."""

    def fn(a):
        return _xtx(a.reshape(-1, a.shape[-1]), backend, interpret, mode)

    return fn


def conv_a_contract(meta: LayerMeta, backend: str, interpret: bool,
                    mode: str):
    """In-forward Ā contraction for a KFC conv layer, from the RAW input:
    the fused im2col+rank-update kernel when the 1-D shape tiles, else
    explicit patches through the shared einsum."""

    def fn(x):
        if backend == "pallas" and x.ndim == 3:
            from repro.kernels.patch_factor import patch_factor_update
            zero = jnp.zeros((meta.a_dim, meta.a_dim), jnp.float32)
            out = patch_factor_update(x, zero, meta, 1.0, 0.0,
                                      interpret=interpret,
                                      autotune_mode=mode)
            if out is not None:
                return out
        from repro.models.conv import append_homog, extract_patches
        p = extract_patches(x, meta.conv_spatial, meta.conv_stride,
                            meta.conv_pad)
        p = p.reshape(-1, p.shape[-1])
        if meta.has_bias:
            p = append_homog(p)
        return _xtx(p, backend, interpret, mode)

    return fn


def g_contract(meta: LayerMeta, backend: str, interpret: bool, mode: str):
    """In-backward G contraction: probe cotangent ``ds`` (..., g_dim) →
    ``Σ cot cotᵀ`` (g_dim, g_dim), delivered as the ``{"gg": ...}`` probe
    cotangent by :func:`apply_gprobe`."""

    def fn(ds):
        return _xtx(ds.reshape(-1, ds.shape[-1]), backend, interpret, mode)

    return fn


def einsum_gg(ds):
    """Backend-free fallback G contraction (a Tagger with a dict probe but
    no installed gcontract entry still produces correct statistics)."""
    d2 = ds.reshape(-1, ds.shape[-1])
    return jnp.einsum("nd,ne->de", d2, d2,
                      preferred_element_type=jnp.float32)


def gg_probe(meta: LayerMeta):
    """The fused layer's probe: a ``(g_dim, g_dim)`` zero the backward fills
    with the contracted second moment (instead of an ``(N, g_dim)`` zero
    filled with raw cotangents)."""
    return {"gg": jnp.zeros((meta.g_dim, meta.g_dim), jnp.float32)}


def apply_gprobe(s, probe_gg, contract):
    """Identity on ``s`` whose VJP emits ``contract(ds)`` as the cotangent
    of ``probe_gg`` — the zero-probe trick with the G-side contraction
    folded into the backward pass itself."""

    @jax.custom_vjp
    def f(s, p):
        return s

    def fwd(s, p):
        return s, None

    def bwd(_, ds):
        return ds, contract(jax.lax.stop_gradient(ds))

    f.defvjp(fwd, bwd)
    return f(s, probe_gg)
