"""Kronecker factor statistics (paper S3, S5).

Per tagged layer we maintain running estimates of
``Ā = E[ā āᵀ]`` (input second moments) and ``G = E[g gᵀ]`` (pre-activation
gradient second moments under the model's predictive distribution), blended
with the paper's exponentially-decayed scheme ``ε = min(1 − 1/k, ε_max)``.

Normalization: every contribution is a raw outer-product **sum**; it is
divided by the *global* token count N of the step.  For MoE expert factors
this bakes the routing probability into the factor (the Fisher is an
expectation over all tokens of the actually-executed compute), so rarely-hit
experts get small factors and the damping floor dominates — the
mathematically consistent treatment.

Factor storage layouts by (kind):
  full : (*lead, d, d)          lead = (n_stack?, n_expert?)
  block: (*lead, nb, db, db)    TP/block-diagonal approximation (DESIGN §3)
  diag : (*lead, d)             vocab-sized dims (embed A, head G)

This module holds the shared *numeric* helpers (contractions, layout math,
decayed blend); state initialization and per-layer dispatch live in the
``CurvatureBlock`` classes under ``core/blocks``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tags import LayerMeta


# ---------------------------------------------------------------------------
# layout decisions
# ---------------------------------------------------------------------------

def factor_layout(dim: int, sharded: bool, tp: int, max_dim: int):
    """Return (kind, blocks) for a factor side of width ``dim``."""
    blocks = 1
    if sharded and tp > 1 and dim % tp == 0:
        blocks = tp
    while dim // blocks > max_dim:
        nxt = blocks * 2
        while dim % nxt and nxt <= dim:
            nxt += blocks
        if nxt > dim:
            return "diag", 1
        blocks = nxt
    return ("block", blocks) if blocks > 1 else ("full", 1)


def factor_shape(dim: int, kind: str, blocks: int, lead=()):
    if kind == "diag":
        return (*lead, dim)
    if kind == "block":
        return (*lead, blocks, dim // blocks, dim // blocks)
    return (*lead, dim, dim)


# ---------------------------------------------------------------------------
# contraction (called inside the model forward for A, and on the probe
# cotangents for G). All inputs are stop-gradient'ed by the caller.
# ---------------------------------------------------------------------------

def outer_sum(x, kind: str, blocks: int, expert: bool = False):
    """Sum of outer products over every batch-ish dim.

    x: (..., d) for dense; (B, E, C, d) for expert layers.
    Returns (d,d) / (nb,db,db) / (d,) — with a leading (E,) if expert.
    Inputs stay in their compute dtype; the MXU accumulates in f32
    (preferred_element_type), so no f32 copy of the activations is made.
    """
    ein = lambda s, a, b: jnp.einsum(s, a, b,
                                     preferred_element_type=jnp.float32)
    d = x.shape[-1]
    if expert:
        b, e, c, _ = x.shape
        if kind == "diag":
            return ein("becd,becd->ed", x, x)
        if kind == "block":
            xr = x.reshape(b, e, c, blocks, d // blocks)
            return ein("becni,becnj->enij", xr, xr)
        return ein("beci,becj->eij", x, x)
    xf = x.reshape(-1, d)
    if kind == "diag":
        return ein("nd,nd->d", xf, xf)
    if kind == "block":
        xr = xf.reshape(-1, blocks, d // blocks)
        return ein("nbd,nbe->bde", xr, xr)
    return ein("nd,ne->de", xf, xf)


def embed_diag_counts(ids, mask, vocab: int):
    """Diagonal Ā for an embedding: token frequencies (sum, not normalized)."""
    flat = ids.reshape(-1)
    w = mask.reshape(-1).astype(jnp.float32)
    return jnp.zeros((vocab,), jnp.float32).at[flat].add(w)


# ---------------------------------------------------------------------------
# running state
# ---------------------------------------------------------------------------

def decay_eps(k, cap: float):
    """Paper S5: ε = min(1 − 1/k, cap); k is the 1-based stats update count."""
    kf = jnp.maximum(k.astype(jnp.float32), 1.0)
    return jnp.minimum(1.0 - 1.0 / kf, cap)


def blend(old, new, eps):
    return jax.tree.map(lambda o, n: eps * o + (1.0 - eps) * n, old, new)


def g_from_cotangent(cot, meta: LayerMeta, n_norm: int):
    """G contribution from probe cotangents of the (1/N)-normalized sampled
    loss: per-token g = N * cot, and G = (1/N) Σ g gᵀ = N Σ cot cotᵀ."""
    cot = jax.lax.stop_gradient(cot)
    if meta.n_stack:
        fn = lambda c: outer_sum(c, meta.g_kind, meta.g_blocks,
                                 expert=meta.kind == "expert")
        s = jax.vmap(fn)(cot)
    else:
        s = outer_sum(cot, meta.g_kind, meta.g_blocks,
                      expert=meta.kind == "expert")
    return s * float(n_norm)


def a_from_record(rec, meta: LayerMeta, n_norm: int):
    """Normalize the in-forward A contraction (already summed) by N."""
    return rec / float(n_norm)
