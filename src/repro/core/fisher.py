"""Exact Fisher quadratic forms via J-products (paper S6.4, S7, Appendix C).

The re-scaling / momentum coefficients need ``δᵢᵀ F δⱼ`` with the *exact*
minibatch Fisher ``F = E[Jᵀ F_R J]``.  Appendix C's trick: compute ``J δ``
once per direction (half the cost of a full Fisher-vector product) and
contract through ``F_R`` analytically:

  categorical:  vᵀFv = Σ_tok [ Σ_c p_c ż_c² − (Σ_c p_c ż_c)² ]
  bernoulli:    vᵀFv = Σ     p(1−p) ż²
  gaussian:     vᵀFv = Σ     ż²

``jax.linearize`` shares one forward pass across all m directions; for LMs
the vocab contraction is chunked so full (N, V) J-products are never
materialized.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.models.head import _pick_chunk
from repro.models.layers import softcap


def _pair_indices(m: int):
    return [(i, j) for i in range(m) for j in range(i, m)]


def quad_lm(model, params, batch, tangents: List, chunk_target: int = 2048):
    """(m, m) matrix of δᵢᵀ F δⱼ for an LM (normalized like the mean loss)."""
    m = len(tangents)

    def hidden_fn(p):
        h, _, _ = model.hidden(p, batch)
        return h

    h, lin = jax.linearize(hidden_fn, params)
    hdots = [lin(t) for t in tangents]

    w = model.head_weight(params)
    if model.cfg.tie_embeddings:
        wdots = [t["embed"].T for t in tangents]
    else:
        wdots = [t["head"] for t in tangents]

    bsz, t, d = h.shape
    n = bsz * t
    mask = batch.get("mask", jnp.ones(batch["labels"].shape, jnp.float32))
    if model.cfg.frontend == "patch":
        p_len = h.shape[1] - batch["labels"].shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((bsz, p_len), jnp.float32), mask], axis=1)

    chunk = _pick_chunk(t, 128)
    nc = t // chunk
    cap = model.cfg.logit_softcap

    hdf = jnp.stack(hdots)                                    # (m, B, T, d)
    wdf = jnp.stack([x.astype(jnp.float32) for x in wdots])   # (m, d, V)
    wf = w.astype(jnp.float32)

    def body(acc, xs):
        hc, hdc, mc = xs                    # (B,c,d),(m,B,c,d),(B,c)
        z = jnp.einsum("bcd,dv->bcv", hc.astype(jnp.float32), wf)
        zd = (jnp.einsum("mbcd,dv->mbcv", hdc.astype(jnp.float32), wf)
              + jnp.einsum("bcd,mdv->mbcv", hc.astype(jnp.float32), wdf))
        if cap:
            sech2 = 1.0 - jnp.tanh(z / cap) ** 2
            zd = zd * sech2[None]
            z = softcap(z, cap)
        p = jax.nn.softmax(z, axis=-1)
        pz = jnp.einsum("bcv,mbcv->mbc", p, zd)               # Σ p ż
        pzz = jnp.einsum("bcv,mbcv,kbcv->mkbc", p, zd, zd)    # Σ p żᵢ żⱼ
        q = (jnp.einsum("mkbc,bc->mk", pzz, mc)
             - jnp.einsum("mbc,kbc,bc->mk", pz, pz, mc))
        return acc + q, None

    xs = (h.reshape(bsz, nc, chunk, d).swapaxes(0, 1),
          hdf.reshape(m, bsz, nc, chunk, d).transpose(2, 0, 1, 3, 4),
          mask.astype(jnp.float32).reshape(bsz, nc, chunk).swapaxes(0, 1))
    acc, _ = jax.lax.scan(jax.checkpoint(body),
                          jnp.zeros((m, m), jnp.float32), xs)
    return acc / n


def quad_logits(logits_fn, params, batch, tangents: List, family: str):
    """(m, m) quadratic for small-output models (MLP autoencoders)."""
    z, lin = jax.linearize(logits_fn, params)
    zds = jnp.stack([lin(t) for t in tangents])               # (m, B, O)
    z = z.astype(jnp.float32)
    zds = zds.astype(jnp.float32)
    n = z.shape[0]
    if family == "categorical":
        p = jax.nn.softmax(z, axis=-1)
        pz = jnp.einsum("no,mno->mn", p, zds)
        q = jnp.einsum("no,mno,kno->mk", p, zds, zds) - jnp.einsum(
            "mn,kn->mk", pz, pz)
    elif family == "bernoulli":
        p = jax.nn.sigmoid(z)
        r = p * (1.0 - p)
        q = jnp.einsum("no,mno,kno->mk", r, zds, zds)
    else:                                                     # gaussian
        q = jnp.einsum("mno,kno->mk", zds, zds)
    return q / n
