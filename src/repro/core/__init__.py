# K-FAC — the paper's primary contribution, as a composable JAX module.
# See kfac.py (optimizer), blocks/ (per-layer curvature-block registry),
# factors.py (S3/S5), inverse.py (S4.2/S6.3), tridiag.py (S4.3/App B),
# fisher.py (S6.4/App C), damping.py (S6.5/S6.6).
