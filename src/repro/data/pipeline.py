"""Deterministic, restart-safe data pipelines.

Every batch is a pure function of (seed, step) — a restored checkpoint
resumes on exactly the token stream it would have seen, on any mesh size
(elastic re-shard safe), with no iterator state to persist.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils.sharding import batch_axes


def _put(arr, mesh, spec):
    if mesh is None:
        return jnp.asarray(arr)
    return jax.device_put(arr, NamedSharding(mesh, spec))


class SyntheticLMData:
    """Markov-chain token stream: learnable (next token is a noisy affine
    function of the current), deterministic per (seed, step)."""

    def __init__(self, vocab: int, seq: int, global_batch: int, mesh=None,
                 seed: int = 0, noise: float = 0.1):
        self.vocab, self.seq, self.gb = vocab, seq, global_batch
        self.mesh, self.seed, self.noise = mesh, seed, noise
        self.a = 6364136223846793005 % max(vocab - 1, 1) + 1
        self.c = 1442695040888963407 % vocab

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        t0 = rng.integers(0, self.vocab, size=(self.gb, 1))
        toks = [t0]
        for _ in range(self.seq):
            nxt = (toks[-1] * self.a + self.c) % self.vocab
            flip = rng.random((self.gb, 1)) < self.noise
            rand = rng.integers(0, self.vocab, size=(self.gb, 1))
            toks.append(np.where(flip, rand, nxt))
        stream = np.concatenate(toks, axis=1).astype(np.int32)
        ba = batch_axes(self.mesh)
        out = {
            "tokens": _put(stream[:, :-1], self.mesh, P(ba, None)),
            "labels": _put(stream[:, 1:], self.mesh, P(ba, None)),
        }
        return out


class TokenFileData:
    """Memory-mapped token-file pipeline (int32 flat token stream on disk).

    Windows are assigned by step with a fixed stride, so any host/mesh
    layout sees the same global batch."""

    def __init__(self, path: str, seq: int, global_batch: int, mesh=None):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq, self.gb, self.mesh = seq, global_batch, mesh
        self.n_windows = (len(self.tokens) - 1) // seq

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        idx = (np.arange(self.gb) + step * self.gb) % self.n_windows
        starts = idx * self.seq
        rows = np.stack([self.tokens[s:s + self.seq + 1] for s in starts])
        ba = batch_axes(self.mesh)
        return {
            "tokens": _put(rows[:, :-1].astype(np.int32), self.mesh, P(ba, None)),
            "labels": _put(rows[:, 1:].astype(np.int32), self.mesh, P(ba, None)),
        }


class SyntheticAutoencoderData:
    """Binary patterns from a low-dim latent — the autoencoder benchmark's
    stand-in for MNIST/CURVES/FACES in this offline container."""

    def __init__(self, dim: int, latent: int, n: int, seed: int = 0,
                 mesh=None):
        rng = np.random.default_rng(seed)
        z = rng.standard_normal((n, latent))
        w = rng.standard_normal((latent, dim)) * 1.5
        probs = 1.0 / (1.0 + np.exp(-(z @ w)))
        self.x = (rng.random((n, dim)) < probs).astype(np.float32)
        self.n = n
        self.mesh = mesh

    def batch(self, step: int, batch_size: Optional[int] = None):
        bs = batch_size or self.n
        idx = (np.arange(bs) + step * bs) % self.n
        x = self.x[idx]
        ba = batch_axes(self.mesh)
        return {"x": _put(x, self.mesh, P(ba, None)),
                "y": _put(x, self.mesh, P(ba, None))}


class SyntheticImageData:
    """Class-template images for the conv classifier: ``y`` picks one of
    ``n_classes`` fixed random templates, ``x`` is that template plus pixel
    noise — learnable, deterministic per (seed, step)."""

    def __init__(self, image_size: int, channels: int, n_classes: int,
                 n: int, seed: int = 0, noise: float = 0.3, mesh=None):
        rng = np.random.default_rng(seed)
        self.templates = rng.standard_normal(
            (n_classes, image_size, image_size, channels)).astype(np.float32)
        self.n_classes, self.n, self.noise = n_classes, n, noise
        self.seed, self.mesh = seed, mesh

    def batch(self, step: int, batch_size: Optional[int] = None):
        bs = batch_size or self.n
        rng = np.random.default_rng((self.seed, step))
        y = rng.integers(0, self.n_classes, size=(bs,)).astype(np.int32)
        x = (self.templates[y]
             + self.noise * rng.standard_normal(
                 self.templates[y].shape).astype(np.float32))
        ba = batch_axes(self.mesh)
        return {"x": _put(x, self.mesh, P(ba, None, None, None)),
                "y": _put(y, self.mesh, P(ba))}


def make_vlm_batch(base: Dict, image_size: int, channels: int, mesh=None,
                   step: int = 0):
    """Raw images for the vision patch frontend (un-stubbed: the model's
    own Conv2D patchifier embeds these)."""
    b = base["tokens"].shape[0]
    rng = np.random.default_rng((7, step))
    images = rng.standard_normal(
        (b, image_size, image_size, channels)).astype(np.float32)
    ba = batch_axes(mesh)
    base = dict(base)
    base["images"] = _put(images, mesh, P(ba, None, None, None))
    return base


def make_audio_batch(base: Dict, n_mels: int, n_frames: int, mesh=None,
                     step: int = 0):
    """Raw log-mel frames for the audio frontend (un-stubbed: the model's
    own Conv1D stem embeds and 2x-downsamples these)."""
    b = base["tokens"].shape[0]
    rng = np.random.default_rng((11, step))
    mels = rng.standard_normal((b, n_frames, n_mels)).astype(np.float32)
    ba = batch_axes(mesh)
    base = dict(base)
    base["mels"] = _put(mels, mesh, P(ba, None, None))
    return base
