"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a, b, c=None, *, alpha=1.0, beta=0.0):
    out = alpha * (a.astype(jnp.float32) @ b.astype(jnp.float32))
    if c is not None and beta != 0.0:
        out = out + beta * c.astype(jnp.float32)
    return out


def factor_update_ref(x, c, *, alpha, beta):
    x = x.astype(jnp.float32)
    return alpha * (x.T @ x) + beta * c.astype(jnp.float32)


def ns_step_ref(m, x):
    m = m.astype(jnp.float32)
    x = x.astype(jnp.float32)
    return x @ (2.0 * jnp.eye(m.shape[-1]) - m @ x)


def ns_inverse_ref(m, iters):
    lam = jnp.max(jnp.sum(jnp.abs(m), axis=-1))
    x = jnp.eye(m.shape[-1], dtype=jnp.float32) / lam
    for _ in range(iters):
        x = ns_step_ref(m, x)
    return 0.5 * (x + x.T)


def precondition_ref(a_inv, v, g_inv):
    return (a_inv.astype(jnp.float32) @ v.astype(jnp.float32)
            @ g_inv.astype(jnp.float32))


def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0):
    """q: (B, Hq, Tq, hd); k, v: (B, Hkv, Tk, hd) — plain softmax attention."""
    b, hq, tq, hd = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, tq, hd).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp = jnp.arange(tq)[:, None]
    kp = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, tq, hd).astype(q.dtype)
