"""Tiled MXU matmul with fused scale/accumulate epilogue.

    out = alpha * (A @ B) + beta * C

Grid is (M/bm, N/bn, K/bk) with the K axis innermost ("arbitrary" semantics);
a VMEM f32 scratch accumulates partial products, and the epilogue (scale +
decayed accumulate) runs on the last K step — this single kernel covers the
K-FAC factor update, the Newton–Schulz iteration's matmuls, and the
preconditioning products.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


DEFAULT_BLOCK = 128


def _kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, alpha, beta, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def matmul(a, b, c=None, *, alpha: float = 1.0, beta: float = 0.0,
           bm: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK,
           bk: int = DEFAULT_BLOCK, out_dtype=jnp.float32,
           interpret: bool = True):
    """a: (M, K); b: (K, N); c: optional (M, N). Dims must tile evenly."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape,
                                                         (bm, bn, bk))
    if c is None:
        c = jnp.zeros((m, n), out_dtype)
        beta = 0.0
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    kernel = functools.partial(_kernel, alpha=alpha, beta=beta,
                               k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c)
