"""Fused decayed Kronecker-factor accumulation (paper S5 + S8 task 4):

    C_new = beta * C_old + alpha * XᵀX

One kernel: the rank-N symmetric update never materializes Xᵀ or an
intermediate product in HBM — X tiles stream through VMEM twice with two
index maps, the MXU does (bk,bm)ᵀ@(bk,bn) per step, and the decay blend is
the epilogue of the last K step.

``alpha``/``beta`` arrive as a scalar-prefetch operand, so they may be traced
values — the optimizer's decay ``ε = min(1 − 1/k, ε_max)`` is a function of
the running stats count and changes every step without recompiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(ab_ref, xa_ref, xb_ref, c_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(xa_ref[...].T, xb_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = (ab_ref[0] * acc_ref[...]
                      + ab_ref[1] * c_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def factor_update(x, c, *, alpha, beta, bm: int = 128,
                  bn: int = 128, bk: int = 128, interpret: bool = True):
    """x: (N, d) activations/gradients; c: (d, d) running factor.

    ``alpha``/``beta`` may be python floats or traced jnp scalars.
    """
    n, d = x.shape
    assert c.shape == (d, d)
    bm, bn, bk = min(bm, d), min(bn, d), min(bk, n)
    assert d % bm == 0 and d % bn == 0 and n % bk == 0, (x.shape, (bm, bn, bk))
    k_steps = n // bk
    grid = (d // bm, d // bn, k_steps)
    ab = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(beta, jnp.float32)])
    kernel = functools.partial(_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bk, bm), lambda i, j, kk, ab: (kk, i)),
                pl.BlockSpec((bk, bn), lambda i, j, kk, ab: (kk, j)),
                pl.BlockSpec((bm, bn), lambda i, j, kk, ab: (i, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, ab: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ab, x, x, c)
