"""Newton–Schulz inverse iteration as two fused Pallas matmuls:

    X' = X (2I − M X)  =  2 X − X (M X)

The identity never materializes: step 1 computes Z = M @ X; step 2 uses the
matmul kernel's epilogue (alpha=-1, beta=2, C=X) to fuse the subtraction.
This is the paper's S8 suggestion (Pan & Schreiber) made MXU-native — the
whole d³ inversion pipeline is plain matmul work.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.matmul import matmul


def ns_step(m, x, *, block: int = 128, interpret: bool = True):
    """One Newton–Schulz iteration for M⁻¹. m, x: (d, d)."""
    z = matmul(m, x, bm=block, bn=block, bk=block, interpret=interpret)
    return matmul(x, z, c=x, alpha=-1.0, beta=2.0, bm=block, bn=block,
                  bk=block, interpret=interpret)


def ns_inverse(m, iters: int, *, block: int = 128, interpret: bool = True):
    """Full inversion: cold start X0 = I/‖M‖_inf, then `iters` steps."""
    d = m.shape[-1]
    lam = jnp.max(jnp.sum(jnp.abs(m), axis=-1))
    x = jnp.eye(d, dtype=jnp.float32) / lam
    for _ in range(iters):
        x = ns_step(m, x, block=block, interpret=interpret)
    return 0.5 * (x + x.T)
