"""Flash-decode kernel: one query token against a long KV cache.

The serve-side counterpart of the Perf-1 cache layout (EXPERIMENTS §Perf):
the key axis is the grid's innermost dimension, so on a sequence-sharded
cache each core streams only its KV slice; the online-softmax scratch
carries (m, l, acc) across key blocks.  The cache's valid length arrives as
a scalar-prefetch argument (position masking without recompilation).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, k_steps, bk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bk)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(k_pos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k, v, length, *, bk: int = 128, interpret: bool = True):
    """q: (B, Hq, hd) one token; k, v: (B, Hkv, S, hd); length: scalar int32
    count of valid cache entries.  Returns (B, Hq, hd)."""
    b, hq, hd = q.shape
    _, hkv, s_len, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bk = min(bk, s_len)
    assert s_len % bk == 0
    k_steps = s_len // bk
    grid = (b, hq, k_steps)
    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                               k_steps=k_steps, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, h, ik, lens: (bb, h, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, h, ik, lens, g=group: (bb, h // g, ik, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, h, ik, lens, g=group: (bb, h // g, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd),
                                   lambda bb, h, ik, lens: (bb, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32).reshape(1), q, k, v)
    return out
