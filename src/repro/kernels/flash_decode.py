"""Flash-decode kernel: one query token per row against a long KV cache.

The serve-side counterpart of the Perf-1 cache layout (EXPERIMENTS §Perf):
the key axis is the grid's innermost dimension, so on a sequence-sharded
cache each core streams only its KV slice; the online-softmax scratch
carries (m, l, acc) across key blocks.  The cache's valid lengths arrive as
a ``(B,)`` scalar-prefetch vector — every batch row masks its own
``[0, len_b)`` prefix (continuous batching: slots decode at *different*
positions), with an optional sliding window (``[len_b - window, len_b)``)
and attention-score softcap so the gemma2-style local layers stay on the
kernel path.

``interpret`` has no hardcoded default: ``None`` resolves from the live
backend (compiled on TPU, interpreter elsewhere), so a direct caller can
never silently run the interpreter on a compiled backend; the jit'd
dispatch layer (``kernels.ops``) threads its ``_STATE`` explicitly like the
other kernels.

``flash_decode_paged`` is the block-indexed paged-attention variant
(PagedAttention/vLLM shape): K/V live in a physical page pool
``(num_pages, page_size, hkv, hd)`` shared by every slot, and each row's
``(max_blocks,)`` page-table row rides in as a *second* scalar-prefetch
operand.  The grid's innermost dimension walks the row's logical pages and
the K/V BlockSpec index maps read the page table to DMA each physical page
in place — no dense ``(B, S_view)`` gather view is ever materialized.
Per-row valid lengths, the sliding window and the softcap behave exactly as
in the dense kernel, so the two are differentially testable against the
same einsum oracle.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, k_steps, bk, window, cap):
    bb = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bk)
    if cap:
        s = cap * jnp.tanh(s / cap)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    length = len_ref[bb]                                 # this row's valid len
    valid = k_pos < length
    if window:
        valid &= k_pos >= length - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k, v, lengths, *, bk: int = 128, window: int = 0,
                 cap: float = 0.0, interpret=None):
    """q: (B, Hq, hd) one token per row; k, v: (B, Hkv, S, hd); lengths:
    ``(B,)`` int32 valid-cache-entry counts (a scalar broadcasts — the
    legacy single-length form).  Returns (B, Hq, hd).

    window > 0 restricts row b to keys in ``[lengths[b]-window,
    lengths[b])``; cap > 0 applies the pre-softmax score softcap.
    ``interpret=None`` resolves from the backend (never silently the
    interpreter on TPU)."""
    b, hq, hd = q.shape
    _, hkv, s_len, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bk = min(bk, s_len)
    assert s_len % bk == 0
    k_steps = s_len // bk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (b,))
    grid = (b, hq, k_steps)
    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                               k_steps=k_steps, bk=bk, window=int(window),
                               cap=float(cap))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, h, ik, lens: (bb, h, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, h, ik, lens, g=group: (bb, h // g, ik, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, h, ik, lens, g=group: (bb, h // g, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd),
                                   lambda bb, h, ik, lens: (bb, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, hd), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
    return out


# ---------------------------------------------------------------------------
# block-indexed paged attention
# ---------------------------------------------------------------------------

def _paged_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, n_blocks, page, window,
                  cap):
    bb = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bh, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (page, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    bh = q.shape[0]
    k_pos = ib * page + jax.lax.broadcasted_iota(jnp.int32, (bh, page), 1)
    length = len_ref[bb]
    valid = k_pos < length                           # beyond-length pages are
    if window:                                       # null/stale: masked out
        valid &= k_pos >= length - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p, v_ref[0, :, 0].astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ib == n_blocks - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_paged(q, k_pool, v_pool, lengths, page_table, *, bh: int = 1,
                       window: int = 0, cap: float = 0.0, interpret=None):
    """Paged decode: one query token per row against a shared page pool.

    q: (B, Hq, hd); k_pool, v_pool: (num_pages, page_size, Hkv, hd);
    lengths: (B,) int32 valid-entry counts; page_table: (B, max_blocks)
    int32 rows of physical page ids (unused tail entries must point at a
    masked page, e.g. the allocator's null page 0).  Returns (B, Hq, hd).

    Both the length vector and the page table ride in as scalar-prefetch
    operands: the grid's innermost dim walks each row's ``max_blocks``
    logical pages, and the K/V index maps look the physical page up in the
    table, so each step DMAs exactly one ``(page_size, hd)`` page — no
    gathered dense view exists anywhere.  ``bh`` is the tunable q-head
    block: heads of one KV group share the streamed pages, so ``bh > 1``
    amortizes the page DMA across the group (autotuner coverage:
    ``candidates("flash_decode_paged", ...)``).
    """
    b, hq, hd = q.shape
    num_pages, page, hkv, _ = k_pool.shape
    assert hq % hkv == 0
    group = hq // hkv
    assert group % bh == 0 and bh <= group, (bh, group)
    n_blocks = page_table.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (b,))
    page_table = jnp.asarray(page_table, jnp.int32)
    grid = (b, hq // bh, n_blocks)
    kernel = functools.partial(_paged_kernel, scale=1.0 / math.sqrt(hd),
                               n_blocks=n_blocks, page=page,
                               window=int(window), cap=float(cap))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bh, hd),
                             lambda bb, jh, ib, lens, pt: (bb, jh, 0)),
                pl.BlockSpec((1, page, 1, hd),
                             lambda bb, jh, ib, lens, pt, g=group, h=bh:
                             (pt[bb, ib], 0, (jh * h) // g, 0)),
                pl.BlockSpec((1, page, 1, hd),
                             lambda bb, jh, ib, lens, pt, g=group, h=bh:
                             (pt[bb, ib], 0, (jh * h) // g, 0)),
            ],
            out_specs=pl.BlockSpec((1, bh, hd),
                                   lambda bb, jh, ib, lens, pt: (bb, jh, 0)),
            scratch_shapes=[
                pltpu.VMEM((bh, 1), jnp.float32),
                pltpu.VMEM((bh, 1), jnp.float32),
                pltpu.VMEM((bh, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, hd), q.dtype),
        interpret=interpret,
    )(lengths, page_table, q, k_pool, v_pool)
    return out
