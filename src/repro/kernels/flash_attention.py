"""Flash attention forward kernel (GQA + causal + sliding window + softcap).

VMEM-tiled online-softmax attention for the serving path of the dense
transformer archs (yi/llama/gemma/phi; gemma2's score softcap and local
windows included).  Grid: (B, Hq, Tq/bq, Tk/bk) with the key axis innermost;
running max/sum and the output accumulator live in VMEM scratch.

The training path keeps the chunked pure-jnp attention (repro.models.layers)
— which doubles as this kernel's oracle in the interpret-mode test sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, cap, k_steps, bq, bk):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B, Hq, Tq, hd);  k, v: (B, Hkv, Tk, hd).  Returns (B, Hq, Tq, hd)."""
    b, hq, tq, hd = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq, bk = min(bq, tq), min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0
    k_steps = tk // bk
    grid = (b, hq, tq // bq, k_steps)
    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        cap=cap, k_steps=k_steps, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bb, h, iq, ik, g=group: (bb, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bb, h, iq, ik, g=group: (bb, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
