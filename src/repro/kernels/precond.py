"""Two-sided K-FAC preconditioning (paper S4.2):

    U = Ā⁻¹ V G⁻¹

as a pair of tiled Pallas matmuls (the (d_in, d_out) grad matrix stays in
HBM; tiles stream through VMEM)."""
from __future__ import annotations

from repro.kernels.matmul import matmul


def precondition(a_inv, v, g_inv, *, block: int = 128,
                 interpret: bool = True):
    """a_inv: (d_in, d_in); v: (d_in, d_out); g_inv: (d_out, d_out)."""
    t = matmul(v, g_inv, bm=block, bn=block, bk=block, interpret=interpret)
    return matmul(a_inv, t, bm=block, bn=block, bk=block, interpret=interpret)
