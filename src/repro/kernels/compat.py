"""JAX version-compatibility shims for the Pallas TPU kernels.

The TPU compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); every kernel
imports the alias from here so the package works on either side of the
rename without per-file version checks.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tile_ok(*dims: int) -> bool:
    """Whether every dim tiles cleanly into the kernels' 128-blocks.

    min(128, d) is used as the block size, so d <= 128 needs only MXU lane
    alignment (d % 8); larger dims must be whole multiples of 128.
    """
    return all(d % 128 == 0 or (0 < d <= 128 and d % 8 == 0) for d in dims)
