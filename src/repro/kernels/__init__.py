# Pallas TPU kernels for K-FAC's compute hot-spots (paper S8 cost model):
#   factor_update   — fused decayed symmetric accumulation C <- eps C + s XᵀX
#   matmul          — tiled MXU matmul with scale/accumulate epilogue
#   ns_step         — Newton–Schulz inverse iteration X <- X(2I − MX)
#   precond         — two-sided preconditioning U = Ā⁻¹ V G⁻¹
#   rotate_rescale  — EKFAC eigenbasis apply Q_A[(Q_AᵀVQ_G)/(s+λ)]Q_Gᵀ with
#                     the damped rescale fused into the middle matmul
#   flash_attention — fwd flash attention (GQA/causal/window/softcap) for the
#                     model substrate's serving path
#   flash_decode    — one-token decode vs a long (sequence-sharded) KV cache;
#                     per-row (B,) valid lengths via scalar prefetch (each
#                     continuous-batching slot masks its own prefix), with
#                     sliding-window and softcap support for gemma2-style
#                     local layers
# ops.py exposes jit'd wrappers with a pure-jnp fallback; ref.py holds the
# oracles the tests sweep against (interpret=True on CPU); compat.py shims
# renamed Pallas TPU APIs across JAX versions and hosts the tile_ok gate
# the curvature blocks (core/blocks) use before routing onto these kernels.
