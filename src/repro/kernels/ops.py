"""jit'd dispatch layer over the Pallas kernels.

``use_pallas(True)`` (or REPRO_USE_PALLAS=1) routes the hot ops through the
kernels — compiled on TPU, interpret-mode on CPU; the default is the pure-jnp
path, which XLA fuses well on CPU and doubles as the reference
implementation.  On a real TPU deployment the launcher flips this on.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import factor_update as _fu
from repro.kernels import matmul as _mm
from repro.kernels import ns_step as _ns
from repro.kernels import precond as _pc
from repro.kernels import ref as _ref

_STATE = {"use_pallas": os.environ.get("REPRO_USE_PALLAS", "0") == "1",
          "interpret": jax.default_backend() != "tpu"}


def use_pallas(on: bool = True, interpret=None):
    _STATE["use_pallas"] = on
    if interpret is not None:
        _STATE["interpret"] = interpret


def enabled() -> bool:
    return _STATE["use_pallas"]


def matmul(a, b, c=None, *, alpha=1.0, beta=0.0):
    if enabled() and all(s % 8 == 0 for s in (*a.shape, *b.shape)):
        return _mm.matmul(a, b, c, alpha=alpha, beta=beta,
                          interpret=_STATE["interpret"])
    return _ref.matmul_ref(a, b, c, alpha=alpha, beta=beta)


def factor_update(x, c, *, alpha, beta):
    """C <- beta C + alpha XᵀX (the S5 decayed running-average update)."""
    if enabled() and x.shape[0] % 8 == 0 and x.shape[1] % 8 == 0:
        return _fu.factor_update(x, c, alpha=alpha, beta=beta,
                                 interpret=_STATE["interpret"])
    return _ref.factor_update_ref(x, c, alpha=alpha, beta=beta)


def ns_inverse(m, iters: int):
    if enabled() and m.shape[-1] % 8 == 0 and m.ndim == 2:
        return _ns.ns_inverse(m, iters, interpret=_STATE["interpret"])
    return _ref.ns_inverse_ref(m, iters)


def precondition(a_inv, v, g_inv):
    if enabled() and all(s % 8 == 0 for s in v.shape):
        return _pc.precondition(a_inv, v, g_inv,
                                interpret=_STATE["interpret"])
    return _ref.precondition_ref(a_inv, v, g_inv)


def flash_decode_ref(q, k, v, lengths, *, window=0, cap=0.0):
    """The masked-einsum decode oracle: (B,Hq,hd) x (B,Hkv,S,hd) with
    per-row ``[0, len_b)`` (optionally windowed, softcapped) masking.  The
    XLA fallback of ``flash_decode`` *and* the differential reference both
    the dense and the paged Pallas kernels are tested against."""
    b, hq, hd = q.shape
    hkv, s_len = k.shape[1], k.shape[2]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (b,))
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32))
    sc = sc / jnp.sqrt(jnp.float32(hd))
    if cap:
        sc = cap * jnp.tanh(sc / cap)
    k_pos = jnp.arange(s_len)
    valid = k_pos[None, :] < lengths[:, None]            # (B, S) per-row mask
    if window:
        valid &= k_pos[None, :] >= lengths[:, None] - window
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, hd).astype(q.dtype)


def flash_decode(q, k, v, lengths, *, bk=128, window=0, cap=0.0):
    """One-token decode vs a long cache: (B,Hq,hd) x (B,Hkv,S,hd).

    ``lengths`` is a ``(B,)`` int32 vector of per-row valid cache entries
    (a scalar broadcasts): continuous-batching slots decode at different
    positions, so each row masks its own ``[0, len_b)`` prefix —
    ``[len_b - window, len_b)`` when ``window`` > 0 (gemma2 local layers);
    ``cap`` > 0 soft-caps the attention scores."""
    b = q.shape[0]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (b,))
    if enabled() and k.shape[2] % bk == 0 and q.shape[-1] % 8 == 0:
        return _fd.flash_decode(q, k, v, lengths, bk=bk, window=window,
                                cap=cap, interpret=_STATE["interpret"])
    return flash_decode_ref(q, k, v, lengths, window=window, cap=cap)


def paged_gather(k_pool, v_pool, page_table):
    """Materialize the dense ``(B, Hkv, S_view, hd)`` gather view of a page
    pool — the serving engine's *oracle* decode route (and the paged
    kernel's differential reference), no longer its hot path."""
    nb = page_table.shape[1]
    num_pages, page, hkv, hd = k_pool.shape
    b = page_table.shape[0]

    def one(pool):
        g = jnp.take(pool, page_table, axis=0)       # (B, nb, P, hkv, hd)
        return g.reshape(b, nb * page, hkv, hd).transpose(0, 2, 1, 3)

    return one(k_pool), one(v_pool)


def flash_decode_paged(q, k_pool, v_pool, lengths, page_table, *, window=0,
                       cap=0.0, tune_mode: str = "off"):
    """Block-indexed paged decode: (B,Hq,hd) against a shared page pool
    ``(num_pages, page_size, Hkv, hd)`` through each row's ``(max_blocks,)``
    page-table row.  The Pallas route walks the pages in place (page table
    as a scalar-prefetch operand — no dense gather view); the XLA fallback
    gathers the view and runs the einsum oracle, so fallback == oracle by
    construction.  ``tune_mode`` threads the autotuner (``REPRO_AUTOTUNE``
    env overrides) for the q-head block ``bh``."""
    b, hq, hd = q.shape
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (b,))
    page_table = jnp.asarray(page_table, jnp.int32)
    if enabled() and hd % 8 == 0:
        kw = {}
        from repro.kernels import autotune as _at
        hkv, page = k_pool.shape[2], k_pool.shape[1]
        cfg = _at.tuned("flash_decode_paged",
                        (b, hq, hkv, hd, page_table.shape[1], page),
                        q.dtype, interpret=_STATE["interpret"],
                        mode=tune_mode)
        if cfg:
            kw.update(cfg)
        return _fd.flash_decode_paged(q, k_pool, v_pool, lengths, page_table,
                                      window=window, cap=cap,
                                      interpret=_STATE["interpret"], **kw)
    kd, vd = paged_gather(k_pool, v_pool, page_table)
    return flash_decode_ref(q, kd, vd, lengths, window=window, cap=cap)


def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0):
    """(B, Hq, Tq, hd) x (B, Hkv, Tk, hd) -> (B, Hq, Tq, hd)."""
    tq, tk, hd = q.shape[2], k.shape[2], q.shape[3]
    if (enabled() and tq % 8 == 0 and tk % 128 == 0 and hd % 8 == 0):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   cap=cap, interpret=_STATE["interpret"])
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    cap=cap)
