"""Fused EKFAC eigenbasis apply (George et al. 1806.03884):

    U = Q_A [ (Q_Aᵀ V Q_G) / (s + lam) ] Q_Gᵀ

Rotate into the Kronecker eigenbasis, damped diagonal rescale, rotate back —
the eigen-mode analogue of :mod:`repro.kernels.precond`'s two-sided apply and
tiled the same way (tiles stream through VMEM; the (d_in, d_out) grad matrix
stays in HBM).  The middle product fuses the rescale into its epilogue via
:func:`matmul_rescale`, so the eigenbasis copy of the gradient is divided by
the damped diagonal as it is produced, never re-read.  ``lam`` rides a
scalar-prefetch operand and may be a traced value (the damping floor /
per-refresh λ), mirroring ``factor_update``'s traced decay ε.

Shapes must tile into the 128-blocks (``compat.tile_ok``); the curvature
blocks fall back to the einsum path in ``core.inverse.apply_eigen`` for
ragged shapes or ``kernel_backend="xla"``, so the backend knob never changes
results — only which kernels execute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.matmul import matmul

DEFAULT_BLOCK = 128


def _kernel(lam_ref, a_ref, b_ref, s_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...]
                      / (s_ref[...].astype(jnp.float32) + lam_ref[0])
                      ).astype(o_ref.dtype)


def matmul_rescale(a, b, s, lam, *, bm: int = DEFAULT_BLOCK,
                   bn: int = DEFAULT_BLOCK, bk: int = DEFAULT_BLOCK,
                   interpret: bool = True):
    """``(A @ B) / (S + lam)`` — a: (M, K); b: (K, N); s: (M, N).

    ``lam`` may be a python float or a traced jnp scalar (scalar prefetch).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and s.shape == (m, n), (a.shape, b.shape, s.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape,
                                                         (bm, bn, bk))
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    lam = jnp.asarray(lam, jnp.float32).reshape(1)
    kernel = functools.partial(_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk, lam: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk, lam: (kk, j)),
                pl.BlockSpec((bm, bn), lambda i, j, kk, lam: (i, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, lam: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lam, a, b, s)


def rotate_rescale(qa, v, qg, s, lam=0.0, *, block: int = DEFAULT_BLOCK,
                   interpret: bool = True):
    """qa: (d_in, d_in); v: (d_in, d_out); qg: (d_out, d_out); s: (d_in, d_out).

    Four tiled matmuls; the rescale fuses into the second's epilogue.
    """
    t = matmul(qa.T, v.astype(jnp.float32), bm=block, bn=block, bk=block,
               interpret=interpret)                     # Q_Aᵀ V
    t = matmul_rescale(t, qg, s, lam, bm=block, bn=block, bk=block,
                       interpret=interpret)             # (· Q_G) / (s + lam)
    t = matmul(qa, t, bm=block, bn=block, bk=block, interpret=interpret)
    return matmul(t, qg.T, bm=block, bn=block, bk=block, interpret=interpret)
