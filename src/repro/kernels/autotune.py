"""Tile-size autotuner for the Pallas kernels (ROADMAP: "make the Pallas
kernels actually win").

Every kernel in this package is parameterized by block shapes (``bm/bn/bk``
for the matmul family, ``bt`` for the patch-factor kernel).  The right tile
depends on the backend, the problem shape and the dtype — a 512-wide factor
update wants different blocking on a TPU MXU than the 128-default that keeps
the interpreter tests fast.  This module:

  * enumerates the **legal** candidate tile configs per ``(kernel, shape)``
    (divisibility + MXU lane/sublane alignment — exactly the constraints the
    kernels assert),
  * times each candidate **on the live backend** with representative random
    inputs (compile excluded, median of a few calls),
  * memoizes the winner in a persistent on-disk JSON cache keyed on
    ``(kernel, shape, dtype, backend)`` so a shape is tuned once per machine,
  * and returns ``None`` whenever no candidate is legal or tuning is off —
    the caller keeps its existing einsum/XLA fallback, so the knob can never
    turn a working path into a crash.

Modes (``KFACConfig.autotune``, overridable via ``REPRO_AUTOTUNE``):

  ``off``    never tune; kernels run with their built-in default blocks.
             Bitwise-identical to the pre-autotuner behavior.
  ``cache``  consult the cache; tune on miss and persist the winner.
  ``force``  re-time every candidate and overwrite the cache entry (use
             after a driver/layout change invalidates old timings).

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  A corrupted, unreadable or
schema-mismatched cache file is treated as empty (re-tune, then rewrite) —
it never raises.  Tuning happens at **trace time** (shapes are static), so
the tuned blocks are ordinary python ints by the time the kernel lowers.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

SCHEMA = 1
MODES = ("off", "cache", "force")
DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                             "autotune.json")

# in-process memo: cache_key -> config dict | None (None = "no legal
# candidate", also memoized so we don't re-enumerate every trace)
_MEMO: Dict[str, Optional[dict]] = {}


def resolve_mode(mode: str) -> str:
    """Config mode, overridden by the REPRO_AUTOTUNE env var when set."""
    env = os.environ.get("REPRO_AUTOTUNE", "").strip().lower()
    out = env if env in MODES else mode
    return out if out in MODES else "off"


def cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", DEFAULT_CACHE)


def backend_tag(interpret: bool) -> str:
    """The cache's backend discriminator: a tuned tile is only valid for the
    platform (and execution mode) it was timed on."""
    b = jax.default_backend()
    return f"{b}_interp" if interpret and b != "tpu" else b


def cache_key(kernel: str, shape, dtype, backend: str) -> str:
    sh = "x".join(str(int(d)) for d in shape)
    return f"{kernel}|{sh}|{jnp.dtype(dtype).name}|{backend}"


# ---------------------------------------------------------------------------
# persistent cache (never raises: corruption -> empty)
# ---------------------------------------------------------------------------

def load_cache(path: Optional[str] = None) -> Dict[str, dict]:
    path = path or cache_path()
    try:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict) or data.get("schema") != SCHEMA:
            return {}
        entries = data.get("entries")
        return entries if isinstance(entries, dict) else {}
    except (OSError, ValueError):
        return {}


def save_entry(key: str, entry: dict, path: Optional[str] = None) -> None:
    path = path or cache_path()
    entries = load_cache(path)
    entries[key] = entry
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"schema": SCHEMA, "entries": entries}, f, indent=1)
        os.replace(tmp, path)
    except OSError:
        pass                     # a read-only FS must not break the step


def cached_entry(kernel: str, shape, dtype, *, interpret: bool,
                 path: Optional[str] = None) -> Optional[dict]:
    """The persisted winner for this problem, or None (no provenance)."""
    key = cache_key(kernel, shape, dtype, backend_tag(interpret))
    return load_cache(path).get(key)


def clear_memo() -> None:
    _MEMO.clear()


# ---------------------------------------------------------------------------
# candidate enumeration (mirrors each kernel's own legality asserts)
# ---------------------------------------------------------------------------

def _dim_blocks(dim: int, caps=(128, 256, 512)) -> List[int]:
    """Legal block sizes for one dim: whole 128-multiples that divide it, or
    the dim itself when it is a sub-128 MXU-lane-aligned size."""
    out = [b for b in caps if b <= dim and dim % b == 0]
    if not out and 0 < dim <= 128 and dim % 8 == 0:
        out = [dim]
    return out


def candidates(kernel: str, shape) -> List[dict]:
    """Candidate tile configs for ``kernel`` on problem ``shape``.

    Shape conventions (what the callers pass):
      factor_update   (n, d)         — x: (N, d), factor: (d, d)
      matmul          (m, k, n)
      precond         (d_in, d_out)  — both two-sided matmuls share a block
      rotate_rescale  (d_in, d_out)
      update_chain    (d_in, d_out)
      patch_factor    (t_out, c, taps, stride)
      flash_decode_paged (b, hq, hkv, hd, max_blocks, page_size)
    """
    if kernel == "factor_update":
        n, d = shape
        return [{"bm": bm, "bn": bn, "bk": bk}
                for bm in _dim_blocks(d) for bn in _dim_blocks(d)
                for bk in _dim_blocks(n)]
    if kernel == "matmul":
        m, k, n = shape
        return [{"bm": bm, "bn": bn, "bk": bk}
                for bm in _dim_blocks(m) for bn in _dim_blocks(n)
                for bk in _dim_blocks(k)]
    if kernel in ("precond", "rotate_rescale", "update_chain"):
        d_in, d_out = shape
        both = [b for b in (128, 256, 512)
                if d_in % b == 0 and d_out % b == 0]
        if not both:
            small = set(_dim_blocks(d_in)) & set(_dim_blocks(d_out))
            both = sorted(small)
        return [{"block": b} for b in both]
    if kernel == "patch_factor":
        t_out, c, taps, stride = shape
        return [{"bt": bt} for bt in (64, 128, 256, 512)
                if bt <= t_out and t_out % bt == 0 and taps <= bt * stride]
    if kernel == "flash_decode_paged":
        # q-head block: heads of one KV group share the streamed page, so
        # bh > 1 amortizes the per-page DMA across the group.  Legal bh
        # divide the GQA group size (block index maps stay group-pure).
        b, hq, hkv, hd, nb, page = shape
        group = hq // max(hkv, 1)
        if hd % 8 != 0 or hkv == 0 or hq % hkv != 0:
            return []
        return [{"bh": bh} for bh in (1, 2, 4, 8, 16)
                if bh <= group and group % bh == 0]
    return []


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def default_timer(fn: Callable[[], jax.Array], iters: int = 3) -> float:
    """Median-free mean wall-clock per call in µs, compile excluded."""
    jax.block_until_ready(fn())          # compile + warm
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _bench_inputs(key, shapes, dtypes):
    ks = jax.random.split(jax.random.PRNGKey(0), len(shapes))
    return [jax.random.normal(k, s).astype(dt)
            for k, s, dt in zip(ks, shapes, dtypes)]


def _make_runner(kernel: str, shape, dtype, interpret: bool,
                 cfg: dict) -> Callable[[], jax.Array]:
    """A zero-arg jitted call of ``kernel`` at ``cfg`` on representative
    random inputs (held alive in the closure)."""
    if kernel == "factor_update":
        from repro.kernels.factor_update import factor_update
        n, d = shape
        x, c = _bench_inputs(0, [(n, d), (d, d)], [dtype, jnp.float32])
        f = jax.jit(lambda x, c: factor_update(
            x, c, alpha=0.05, beta=0.95, interpret=interpret, **cfg))
        return lambda: f(x, c)
    if kernel == "matmul":
        from repro.kernels.matmul import matmul
        m, k, n = shape
        a, b = _bench_inputs(1, [(m, k), (k, n)], [dtype, dtype])
        f = jax.jit(lambda a, b: matmul(a, b, interpret=interpret, **cfg))
        return lambda: f(a, b)
    if kernel == "precond":
        from repro.kernels.precond import precondition
        d_in, d_out = shape
        a, v, g = _bench_inputs(2, [(d_in, d_in), (d_in, d_out),
                                    (d_out, d_out)], [jnp.float32] * 3)
        f = jax.jit(lambda a, v, g: precondition(
            a, v, g, interpret=interpret, **cfg))
        return lambda: f(a, v, g)
    if kernel == "rotate_rescale":
        from repro.kernels.rotate_rescale import rotate_rescale
        d_in, d_out = shape
        qa, v, qg, s = _bench_inputs(
            3, [(d_in, d_in), (d_in, d_out), (d_out, d_out),
                (d_in, d_out)], [jnp.float32] * 4)
        f = jax.jit(lambda qa, v, qg, s: rotate_rescale(
            qa, v, qg, s, lam=1e-6, interpret=interpret, **cfg))
        return lambda: f(qa, v, qg, s)
    if kernel == "update_chain":
        from repro.kernels.update_chain import precond_momentum
        d_in, d_out = shape
        a, v, g, m = _bench_inputs(
            4, [(d_in, d_in), (d_in, d_out), (d_out, d_out),
                (d_in, d_out)], [jnp.float32] * 4)
        f = jax.jit(lambda a, v, g, m: precond_momentum(
            a, v, g, m, alpha=-0.05, mu=0.9, interpret=interpret,
            **cfg)[0])
        return lambda: f(a, v, g, m)
    if kernel == "patch_factor":
        from repro.kernels.patch_factor import patch_factor
        t_out, c, taps, stride = shape
        d = taps * c
        x, old = _bench_inputs(5, [(2, t_out * stride + taps, c), (d, d)],
                               [dtype, jnp.float32])
        f = jax.jit(lambda x, old: patch_factor(
            x, old, taps=taps, stride=stride, t_out=t_out, alpha=0.05,
            beta=0.95, interpret=interpret, **cfg))
        return lambda: f(x, old)
    if kernel == "flash_decode_paged":
        from repro.kernels.flash_decode import flash_decode_paged
        b, hq, hkv, hd, nb, page = shape
        num_pages = 1 + b * nb
        q, kp, vp = _bench_inputs(
            6, [(b, hq, hd), (num_pages, page, hkv, hd),
                (num_pages, page, hkv, hd)], [dtype] * 3)
        rs = jax.random.split(jax.random.PRNGKey(7), 1)[0]
        pt = jax.random.permutation(
            rs, jnp.arange(1, num_pages, dtype=jnp.int32)
        )[:b * nb].reshape(b, nb)
        lens = jnp.full((b,), nb * page, jnp.int32)
        f = jax.jit(lambda q, kp, vp, lens, pt: flash_decode_paged(
            q, kp, vp, lens, pt, interpret=interpret, **cfg))
        return lambda: f(q, kp, vp, lens, pt)
    raise KeyError(f"no autotune runner for kernel {kernel!r}")


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------

def tuned(kernel: str, shape, dtype, *, interpret: bool, mode: str = "off",
          timer: Optional[Callable] = None,
          path: Optional[str] = None) -> Optional[dict]:
    """The winning tile config (kwargs for the kernel) or ``None``.

    ``None`` means: tuning is off, no candidate is legal, or every candidate
    failed to run — the caller proceeds exactly as before (default blocks or
    its einsum fallback).  Tuning happens eagerly (shapes are static python
    ints), so this is safe to call at trace time; results are memoized
    in-process and persisted on disk.
    """
    mode = resolve_mode(mode)
    if mode == "off":
        return None
    shape = tuple(int(d) for d in shape)
    key = cache_key(kernel, shape, dtype, backend_tag(interpret))
    if mode != "force" and key in _MEMO:
        return _MEMO[key]
    if mode != "force":
        entry = load_cache(path).get(key)
        if entry is not None and isinstance(entry.get("cfg"), (dict,
                                                               type(None))):
            cfg = entry["cfg"]
            cands = candidates(kernel, shape)
            # stale guard: a cached winner that is no longer a legal
            # candidate (kernel constraints changed) forces a re-tune
            if cfg is None or cfg in cands:
                _MEMO[key] = cfg
                return cfg
    cfg = _tune(kernel, shape, dtype, interpret, timer or default_timer,
                key, path)
    _MEMO[key] = cfg
    return cfg


def _tune(kernel, shape, dtype, interpret, timer, key, path):
    cands = candidates(kernel, shape)
    best, best_us = None, float("inf")
    timings = {}
    for cfg in cands:
        try:
            us = float(timer(_make_runner(kernel, shape, dtype, interpret,
                                          cfg)))
        except Exception:        # noqa: BLE001 — an illegal lowering is a
            continue             # declined candidate, never a crash
        timings[json.dumps(cfg, sort_keys=True)] = us
        if us < best_us:
            best, best_us = cfg, us
    save_entry(key, {"cfg": best,
                     "us": None if best is None else best_us,
                     "timings": timings}, path)
    return best
