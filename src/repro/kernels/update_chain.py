"""Fused precondition + momentum + norm accumulation (paper S4.2 + S7):

    D = alpha * (A^-1 V G^-1) + mu * M,      ||D||² as a kernel by-product

The fixed-learning-rate update chain (``use_rescale=False``) used to run as
three separate ops — precondition, momentum axpy, global-norm clip — each
materializing a weight-shaped intermediate in HBM and the clip *re-reading*
the finished update just to take its norm.  Here the whole chain is two
kernels:

  * ``T = V G^-1`` — the plain tiled matmul, and
  * one epilogue kernel that computes ``alpha·(A^-1 T) + mu·M`` and, while
    the finished ``(bm, bn)`` tile is still in VMEM, accumulates its squared
    Frobenius norm into a per-tile partials grid.

The caller sums the partials (a ``(grid_m, grid_n)`` array, a few hundred
floats) and folds the clip factor ``min(1, c/||D||)`` into the parameter
apply — the update tensor itself is written exactly once and never re-read.
``alpha``/``mu`` ride scalar prefetch, so the optimizer's traced step sizes
never recompile; tile sizes come from the autotuner when enabled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.matmul import matmul

DEFAULT_BLOCK = 128


def _kernel(am_ref, a_ref, t_ref, m_ref, o_ref, sq_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], t_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        out = (am_ref[0] * acc_ref[...]
               + am_ref[1] * m_ref[...].astype(jnp.float32))
        o_ref[...] = out.astype(o_ref.dtype)
        sq_ref[0, 0] = jnp.sum(out * out)


def axpy_momentum(a_inv, t, mom, alpha, mu, *, bm: int = DEFAULT_BLOCK,
                  bn: int = DEFAULT_BLOCK, bk: int = DEFAULT_BLOCK,
                  interpret: bool = True):
    """``D = alpha·(a_inv @ t) + mu·mom`` plus per-tile ``Σ D²`` partials.

    a_inv: (M, K); t: (K, N); mom: (M, N).  Returns ``(D, sq_partials)``
    with ``sq_partials`` shaped ``(M//bm, N//bn)``.  ``alpha``/``mu`` may be
    python floats or traced jnp scalars (scalar prefetch).
    """
    m, k = a_inv.shape
    k2, n = t.shape
    assert k == k2 and mom.shape == (m, n), (a_inv.shape, t.shape, mom.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a_inv.shape,
                                                         t.shape, (bm, bn, bk))
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    am = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(mu, jnp.float32)])
    kernel = functools.partial(_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk, am: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk, am: (kk, j)),
                pl.BlockSpec((bm, bn), lambda i, j, kk, am: (i, j)),
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), lambda i, j, kk, am: (i, j)),
                pl.BlockSpec((1, 1), lambda i, j, kk, am: (i, j)),
            ],
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m // bm, n // bn), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(am, a_inv, t, mom)


def precond_momentum(a_inv, v, g_inv, mom, *, alpha, mu,
                     block: int = DEFAULT_BLOCK, interpret: bool = True):
    """The fused chain for one Kronecker block:

        D = alpha · (A^-1 V G^-1) + mu · mom,   plus ``Σ D²`` (a scalar)

    a_inv: (d_in, d_in); v: (d_in, d_out); g_inv: (d_out, d_out);
    mom: (d_in, d_out).  Returns ``(D, sqnorm)``.
    """
    t = matmul(v.astype(jnp.float32), g_inv, bm=block, bn=block, bk=block,
               interpret=interpret)
    d, sq = axpy_momentum(a_inv, t, mom, alpha, mu, bm=block, bn=block,
                          bk=block, interpret=interpret)
    return d, jnp.sum(sq)
