"""Fused im2col + decayed KFC patch-factor accumulation (1602.01407 §3):

    Ā_new = beta * Ā_old + alpha * PᵀP,    P = im2col(x)

without ever materializing the ``(B·T_out, K·C)`` patch matrix ``P`` in HBM.
The A-factor of a 1-D conv has a tap-pair block structure

    Ā[(k₁,c₁), (k₂,c₂)] = Σ_{b,t} x[b, t·s + k₁, c₁] · x[b, t·s + k₂, c₂]

so the kernel grids over tap pairs ``(k₁, k₂)`` and streams time tiles of
the *raw* input through VMEM once per pair: each step loads two consecutive
``(bt·s, C)`` time blocks (the second is the halo for the tap shift),
dynamically slices the tap offset, subsamples the stride in-register, and
feeds the MXU a ``(bt, C)ᵀ @ (bt, C)`` rank-update.  The decay blend is the
epilogue of the last step; ``alpha``/``beta`` ride scalar prefetch so the
optimizer's traced ``ε = min(1 − 1/k, ε_max)`` never recompiles.

The homogeneous bias row/column (``ā = [patch; 1]``) is a spatial *sum* of
the raw input — O(T·C), not O(T·C²·K²) — so :func:`patch_factor_update`
computes the border with cheap strided slices and splices it around the
kernel's core.  Shapes that don't tile (see :func:`patch_tile_ok`) return
``None`` and the caller falls back to the einsum path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams, tile_ok


def conv_pad_amounts(t: int, k: int, stride: int, padding: str):
    """(lo, hi) zero-padding of one conv dim under lax "SAME"/"VALID"."""
    if padding == "VALID":
        return 0, 0
    out = -(-t // stride)
    total = max((out - 1) * stride + k - t, 0)
    return total // 2, total - total // 2


def patch_tile_ok(c: int, t_out: int, taps: int = 1,
                  stride: int = 1) -> bool:
    """Whether the fused patch-factor kernel applies: one clean ``(C, C)``
    MXU tile per tap pair, a positive tiling output-position count, and
    taps that fit inside one time block (the halo covers one block only)."""
    return (0 < c <= 128 and c % 8 == 0 and t_out > 0 and tile_ok(t_out)
            and taps <= min(128, t_out) * stride)


def _kernel(ab_ref, x0_ref, x1_ref, c_ref, o_ref, acc_ref, *, bt, stride,
            n_steps):
    ki = pl.program_id(0)
    kj = pl.program_id(1)
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # two consecutive time blocks: the halo for the (sub-block) tap shifts
    buf = jnp.concatenate([x0_ref[0], x1_ref[0]], axis=0)   # (2·bt·s, C)

    def rows(k):
        w = jax.lax.dynamic_slice_in_dim(buf, k, bt * stride, axis=0)
        if stride > 1:
            w = w.reshape(bt, stride, w.shape[-1])[:, 0, :]
        return w

    acc_ref[...] += jnp.dot(rows(ki).T, rows(kj),
                            preferred_element_type=jnp.float32)

    @pl.when(r == n_steps - 1)
    def _done():
        o_ref[...] = (ab_ref[0] * acc_ref[...]
                      + ab_ref[1] * c_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def patch_factor(x, c, *, taps: int, stride: int, t_out: int, alpha, beta,
                 bt: int = 128, interpret: bool = True):
    """x: (B, T_pad, C) conv-padded raw input; c: (K·C, K·C) running factor.

    Patch row ``(b, t, k)`` is ``x[b, t·stride + k]`` for ``t < t_out``;
    ``alpha``/``beta`` may be python floats or traced jnp scalars.
    """
    b, t_in, ch = x.shape
    d = taps * ch
    assert c.shape == (d, d), (c.shape, d)
    bt = min(bt, t_out)
    assert t_out % bt == 0 and taps <= bt * stride, (t_out, bt, taps, stride)
    nt = t_out // bt
    blk = bt * stride
    # one extra zero block so the halo read of the last tile stays in bounds
    full = (nt + 1) * blk
    assert t_in <= full, (t_in, full)
    if t_in < full:
        x = jnp.pad(x, ((0, 0), (0, full - t_in), (0, 0)))
    n_steps = b * nt
    ab = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(beta, jnp.float32)])
    kernel = functools.partial(_kernel, bt=bt, stride=stride, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(taps, taps, n_steps),
            in_specs=[
                pl.BlockSpec((1, blk, ch),
                             lambda i, j, r, ab: (r // nt, r % nt, 0)),
                pl.BlockSpec((1, blk, ch),
                             lambda i, j, r, ab: (r // nt, r % nt + 1, 0)),
                pl.BlockSpec((ch, ch), lambda i, j, r, ab: (i, j)),
            ],
            out_specs=pl.BlockSpec((ch, ch), lambda i, j, r, ab: (i, j)),
            scratch_shapes=[pltpu.VMEM((ch, ch), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ab, x, x, c)


def patch_factor_update(x, old, meta, alpha, beta, *, bt: int = 128,
                        interpret: bool = True,
                        autotune_mode: str = "off"):
    """The ``ConvKronecker`` A-side route: fused ``Ā ← β Ā + α P̂ᵀP̂`` for a
    1-D conv from the raw input, or ``None`` when the shape doesn't tile
    (the caller falls back to the einsum path).

    x: (B, T, C) raw (un-padded) input; old: (a_dim, a_dim) running factor
    with the homogeneous row/column last when ``meta.has_bias``.
    ``autotune_mode`` != "off" looks up a tuned time-tile ``bt``.
    """
    if len(meta.conv_spatial) != 1:
        return None
    (k,), (s,) = meta.conv_spatial, meta.conv_stride
    b, t, ch = x.shape
    from repro.models.conv import conv_out_len
    t_out = conv_out_len(t, k, s, meta.conv_pad)
    if not patch_tile_ok(ch, t_out, k, s):
        return None
    if autotune_mode != "off":
        from repro.kernels.autotune import tuned
        cfg = tuned("patch_factor", (t_out, ch, k, s), x.dtype,
                    interpret=interpret, mode=autotune_mode)
        if cfg:
            bt = cfg["bt"]
    lo, hi = conv_pad_amounts(t, k, s, meta.conv_pad)
    xp = jnp.pad(x, ((0, 0), (lo, hi), (0, 0))) if lo or hi else x
    d = k * ch
    core_old = old[:d, :d] if meta.has_bias else old
    core = patch_factor(xp, core_old, taps=k, stride=s, t_out=t_out,
                        alpha=alpha, beta=beta, bt=bt, interpret=interpret)
    if not meta.has_bias:
        return core
    # homogeneous border: Σ_t patch (per tap, a strided slice sum) + count
    m = jnp.concatenate(
        [jnp.sum(xp[:, kk:kk + t_out * s:s, :].astype(jnp.float32), (0, 1))
         for kk in range(k)])
    cnt = jnp.float32(b * t_out)
    row = beta * old[d, :d] + alpha * m
    corner = beta * old[d, d] + alpha * cnt
    col = beta * old[:d, d] + alpha * m
    top = jnp.concatenate([core, col[:, None]], axis=1)
    bot = jnp.concatenate([row, corner[None]])[None, :]
    return jnp.concatenate([top, bot], axis=0)
