"""Curvature-as-a-product: optimizer-free influence & uncertainty service.

The training-time EKFAC state, exported as a :class:`CurvatureBundle`,
queryable for inverse-Hessian-vector products / influence scores
(:class:`InfluenceEngine`) and Laplace predictive variance on the serving
path (:class:`LaplaceHead`) — no optimizer required on the consumer side.
"""
from repro.curvature.bundle import (
    BUNDLE_SCHEMA,
    BundleWriter,
    CurvatureBundle,
    load_bundle,
    save_bundle,
    snapshot_bundle,
)
from repro.curvature.ihvp import (
    InfluenceEngine,
    load_influence_engine,
    per_example_grads,
)
from repro.curvature.uncertainty import LaplaceHead

__all__ = [
    "BUNDLE_SCHEMA",
    "BundleWriter",
    "CurvatureBundle",
    "InfluenceEngine",
    "LaplaceHead",
    "load_bundle",
    "load_influence_engine",
    "per_example_grads",
    "save_bundle",
    "snapshot_bundle",
]
