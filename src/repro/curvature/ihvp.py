"""Batched EKFAC inverse-Hessian-vector products + influence functions.

The damped Kronecker-factored Fisher ``F + λI ≈ ⊕_i (Ā_i+πγI)⊗(G_i+γ/πI)``
inverts in closed form through the bundle's eigenbases: per block,

    (F_i + λI)^{-1} V  =  Q_A [ (Q_Aᵀ V Q_G) / (s + damp) ] Q_Gᵀ

(:func:`repro.core.inverse.apply_eigen`), so an inverse-Hessian-vector
product is three matmuls and an elementwise rescale per block — the same
``rotate_rescale`` contraction the optimizer runs, and it routes through
the same Pallas kernel when shapes tile (``backend="pallas"``; the einsum
path is the fallback and the differential oracle).  Untagged (elementwise)
params use the bundle's running diagonal curvature: ``g / (d + λ + η)``.

Influence functions (Koh & Liang form, EKFAC-approximated à la George et
al. / Grosse et al.): the influence of a training example ``z`` on a query
``z_q`` is

    I(z, z_q) = ⟨ ∇L(z_q), (F + λI)^{-1} ∇L(z) ⟩

:class:`InfluenceEngine` computes the iHVP once per query and dots it
against a stack of per-example training gradients (:func:`per_example_grads`
— a vmapped single-example gradient pass), with a top-k retrieval helper
for attribution queries.

Everything here is built from a :class:`~repro.curvature.bundle
.CurvatureBundle` alone — no optimizer, no ``KFACEngine``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import KFACConfig
from repro.core.blocks import build_blocks
from repro.curvature.bundle import CurvatureBundle
from repro.utils import tree as T


def _path_key(keypath) -> str:
    out = []
    for k in keypath:
        for attr in ("key", "idx", "name"):
            if hasattr(k, attr):
                out.append(str(getattr(k, attr)))
                break
        else:
            out.append(str(k))
    return "::".join(out)


def per_example_grads(model, params, batch, rng=None):
    """Per-example loss gradients: a stacked grads pytree with a leading
    ``N`` axis (one gradient per batch row), via vmap of the
    single-example gradient pass over the batch axis."""
    rng = jax.random.PRNGKey(0) if rng is None else rng

    def one(row):
        b1 = jax.tree.map(lambda x: x[None], row)

        def f(p):
            (lt, _), _ = model.loss(p, None, b1, rng, mode="plain")
            return lt

        return jax.grad(f)(params)

    return jax.vmap(one)(batch)


class InfluenceEngine:
    """EKFAC iHVP / influence-score service over a curvature bundle.

    Blocks are resolved from the bundle's serialized metas through the
    same registry the optimizer uses (``core/blocks``), so every factor
    layout (dense, TP-blocked, diagonal, embed, head, expert, conv) gets
    its structured apply — and dense blocks get the Pallas
    ``rotate_rescale`` route + autotune wiring for free.

    ``extra_damping`` is added on top of the bundle's baked-in factored
    Tikhonov diagonal (useful to sweep λ at query time without
    re-exporting).
    """

    def __init__(self, bundle: CurvatureBundle, *, backend: str = "xla",
                 autotune: str = "off", extra_damping: float = 0.0):
        self.bundle = bundle
        self.cfg = KFACConfig(kernel_backend=backend, autotune=autotune)
        self.blocks = build_blocks(bundle.metas, self.cfg)
        self.lam_eta = float(bundle.lam + bundle.eta + extra_damping)
        self.extra = float(extra_damping)
        self._tagged = {m.param_path for m in bundle.metas.values()}
        self._eig = {
            name: {k: (None if v is None else jnp.asarray(v))
                   for k, v in bundle.eigen[name].items()}
            for name in bundle.eigen}
        if self.extra:
            self._eig = {name: dict(e, damp=e["damp"] + self.extra)
                         for name, e in self._eig.items()}
        self._diag = {k: jnp.asarray(v) for k, v in bundle.diag.items()}
        self._ihvp_jit = jax.jit(self._ihvp_impl)
        self._ihvp_batched_jit = jax.jit(self._ihvp_batched_impl)
        self._influence_jit = jax.jit(self._influence_impl)

    # ------------------------------------------------------------------
    # iHVP
    # ------------------------------------------------------------------
    def _untagged(self, grads):
        """Diagonal-curvature apply for every non-block leaf; tagged
        leaves pass through and are overwritten by the block loop."""
        tagged = self._tagged

        def leaf(kp, g):
            g = g.astype(jnp.float32)
            path = tuple(
                getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))
                for k in kp)
            if path in tagged:
                return g
            d = self._diag.get(_path_key(kp))
            if d is None:
                return g / self.lam_eta
            return g / (d + self.lam_eta)      # trailing dims broadcast

        return jax.tree_util.tree_map_with_path(leaf, grads)

    def _ihvp_impl(self, grads):
        out = self._untagged(grads)
        for name, blk in self.blocks.items():
            v = T.get_path(grads, blk.meta.param_path)
            out = T.set_path(out, blk.meta.param_path,
                             blk.ihvp(self._eig[name], v))
        return out

    def _ihvp_batched_impl(self, grads_stacked):
        """Stacked queries: every leaf carries a leading ``N`` axis.  The
        untagged diagonal broadcasts; blocks run their batched route (the
        Pallas contraction rides under the vmap unchanged)."""
        out = self._untagged(grads_stacked)
        for name, blk in self.blocks.items():
            v = T.get_path(grads_stacked, blk.meta.param_path)
            out = T.set_path(out, blk.meta.param_path,
                             blk.ihvp_batched(self._eig[name], v))
        return out

    def ihvp(self, grads):
        """``(F + λI)^{-1} g`` for one gradient pytree."""
        return self._ihvp_jit(grads)

    def ihvp_batched(self, grads_stacked):
        """Batched iHVP over a stacked gradient pytree (leading N axis)."""
        return self._ihvp_batched_jit(grads_stacked)

    # ------------------------------------------------------------------
    # influence
    # ------------------------------------------------------------------
    def _influence_impl(self, query_grads, train_grads_stacked):
        q = self._ihvp_impl(query_grads)
        return jax.vmap(
            lambda tg: T.tree_dot(q, tg))(train_grads_stacked)

    def influence(self, query_grads, train_grads_stacked):
        """Influence scores ``⟨∇L_q, (F+λI)^{-1}∇L_i⟩`` of every training
        example ``i`` (stacked gradients, leading N) on one query; the
        iHVP is taken on the query side (the product is symmetric in
        exact arithmetic)."""
        return self._influence_jit(query_grads, train_grads_stacked)

    def self_influence(self, train_grads_stacked):
        """Per-example self-influence ``⟨∇L_i, (F+λI)^{-1}∇L_i⟩`` — the
        memorization / atypicality score; always non-negative."""
        ih = self._ihvp_batched_jit(train_grads_stacked)
        return jax.vmap(T.tree_dot)(ih, train_grads_stacked)

    @staticmethod
    def topk(scores, k: int):
        """Top-k retrieval over influence scores: (values, indices)."""
        k = min(int(k), int(scores.shape[-1]))
        return jax.lax.top_k(scores, k)


def load_influence_engine(path: str, *, backend: str = "xla",
                          autotune: str = "off",
                          extra_damping: float = 0.0) -> InfluenceEngine:
    """One-call loader: bundle from disk -> ready iHVP engine."""
    from repro.curvature.bundle import load_bundle
    return InfluenceEngine(load_bundle(path), backend=backend,
                           autotune=autotune, extra_damping=extra_damping)
