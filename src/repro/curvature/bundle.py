"""Curvature bundles: the optimizer's EKFAC state as a serving artifact.

A *bundle* is the minimal, optimizer-free snapshot of the Fisher
approximation K-FAC maintains during training: per-block factor eigenbases
``Q_A, Q_G``, the eigenbasis diagonals ``s`` / ``damp`` (George et al.
1806.03884 — together they define the damped inverse apply
``Q_A [(Q_Aᵀ V Q_G)/(s+damp)] Q_Gᵀ``), the diagonal curvature of untagged
params, and the damping metadata ``(lam, gamma, eta)`` under which the
state was taken.  That is exactly what influence functions and Laplace
posteriors need — and nothing else: no optimizer, no model, no
``KFACEngine`` is required to load one (:func:`load_bundle` reconstructs
the :class:`~repro.core.tags.LayerMeta` registry straight from the
manifest).

On-disk layout (schema-versioned, checkpoint-adjacent)::

    <path>/
      arrays.npz     — "eig::<block>::{qa,qg,s,damp}" + "diag::<param-key>"
      manifest.json  — schema, step, lam/gamma/eta, dtype, per-block metas
      COMMIT         — written last; absence marks a torn bundle

Bundles are written *next to* the checkpoint step dirs (the checkpoint
manifest's ``curvature_bundle`` pointer, schema v4) but never inside them:
the checkpointer renames its step dir asynchronously and a co-located
bundle would race that rename.

Export is non-blocking on the training step, the same immutable-snapshot
idea as the distributed ``OverlapController``: jax arrays are immutable, so
:func:`snapshot_bundle` just captures references on the training thread and
:class:`BundleWriter` fetches + serializes them on a daemon thread.

Optional ``dtype="bfloat16"`` storage halves the eigenbasis bytes: numpy
has no native bf16, so bases are stored as their ``uint16`` bit pattern and
viewed back through ``ml_dtypes.bfloat16`` on load (``s``/``damp`` — the
curvature magnitudes themselves — always stay float32).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.tags import LayerMeta

BUNDLE_SCHEMA = 1
_EIG_KEYS = ("qa", "qg", "s", "damp")
_BASIS_KEYS = ("qa", "qg")          # the only keys eligible for bf16 storage
_TUPLE_FIELDS = ("param_path", "conv_spatial", "conv_stride")


@dataclasses.dataclass
class CurvatureBundle:
    """In-memory bundle: eigen state + metas + damping metadata.

    ``eigen[name]`` is the per-block ``{"qa", "qg", "s", "damp"}`` dict
    (``qa``/``qg`` are None on diagonal factor sides — identity rotation);
    ``diag`` maps flat ``"::"``-joined param paths of *untagged* params to
    their running squared-gradient diagonal.
    """

    step: int
    lam: float
    gamma: float
    eta: float
    metas: Dict[str, LayerMeta]
    eigen: Dict[str, Dict[str, Any]]
    diag: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema: int = BUNDLE_SCHEMA

    @property
    def block_names(self):
        return sorted(self.eigen)


def _meta_to_json(meta: LayerMeta) -> dict:
    return dataclasses.asdict(meta)


def _meta_from_json(d: dict) -> LayerMeta:
    d = dict(d)
    for f in _TUPLE_FIELDS:
        if f in d:
            d[f] = tuple(d[f])
    return LayerMeta(**d)


# ---------------------------------------------------------------------------
# snapshot (training side — needs the engine; loading never does)
# ---------------------------------------------------------------------------

def snapshot_bundle(engine, state) -> Optional["CurvatureBundle"]:
    """Capture the engine's current curvature as a bundle (device arrays —
    cheap, non-blocking; hand the result to :class:`BundleWriter`).

    In ``inv_mode="eigen"`` the live EKFAC state is referenced as-is; the
    other inv_modes compute a fresh eigen state from the running factors
    (one eigh per block — right after which ``apply_eigen`` equals the
    damped ``eigh`` inverse exactly).  Returns None for optimizers without
    curvature blocks (first-order baselines).
    """
    blocks = getattr(engine, "blocks", None)
    if not blocks:
        return None
    eigen = {}
    for name, blk in blocks.items():
        if getattr(engine, "eigen", False) and name in state.inv:
            eigen[name] = dict(state.inv[name])
        else:
            eigen[name] = blk.eigen_state(state.factors[name], state.gamma)
    diag = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state.diag)[0]:
        if leaf.size == 0:            # tagged params carry a (0,) placeholder
            continue
        key = "::".join(_key_str(k) for k in path)
        diag[key] = leaf
    return CurvatureBundle(
        step=int(state.step), lam=float(state.lam), gamma=float(state.gamma),
        eta=float(getattr(engine.cfg, "eta", 0.0)),
        metas={name: blk.meta for name, blk in blocks.items()},
        eigen=eigen, diag=diag)


def _key_str(k) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

def _to_store(arr: np.ndarray, key: str, dtype: str) -> np.ndarray:
    if dtype == "bfloat16" and key in _BASIS_KEYS:
        import ml_dtypes
        return arr.astype(ml_dtypes.bfloat16).view(np.uint16)
    return arr


def _from_store(arr: np.ndarray, key: str, dtype: str) -> np.ndarray:
    if dtype == "bfloat16" and key in _BASIS_KEYS:
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16).astype(np.float32)
    return arr


def save_bundle(bundle: CurvatureBundle, path: str,
                dtype: str = "float32") -> str:
    """Serialize ``bundle`` at ``path`` (atomic: tmp dir + rename + COMMIT).

    ``dtype``: "float32" | "bfloat16" — storage precision of the
    eigen*bases* only; diagonals always stay float32."""
    if dtype not in ("float32", "bfloat16"):
        raise ValueError(f"unknown bundle dtype {dtype!r}")
    arrays: Dict[str, np.ndarray] = {}
    for name in bundle.block_names:
        for k in _EIG_KEYS:
            v = bundle.eigen[name].get(k)
            if v is None:
                continue
            arrays[f"eig::{name}::{k}"] = _to_store(
                np.asarray(jax.device_get(v), np.float32), k, dtype)
    for key, v in bundle.diag.items():
        arrays[f"diag::{key}"] = np.asarray(jax.device_get(v), np.float32)
    manifest = {
        "schema": bundle.schema, "step": bundle.step,
        "lam": bundle.lam, "gamma": bundle.gamma, "eta": bundle.eta,
        "dtype": dtype,
        "blocks": {name: _meta_to_json(bundle.metas[name])
                   for name in bundle.block_names},
        "keys": sorted(arrays), "time": time.time(),
    }
    tmp = path + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    shutil.rmtree(path, ignore_errors=True)
    os.rename(tmp, path)
    return path


def load_bundle(path: str) -> CurvatureBundle:
    """Load a bundle written by :func:`save_bundle` — engine-free: the
    block metas come from the manifest, not from any model object."""
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed curvature bundle at {path!r}")
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    if man["schema"] > BUNDLE_SCHEMA:
        raise ValueError(f"bundle at {path!r} has schema {man['schema']} > "
                         f"supported {BUNDLE_SCHEMA}")
    dtype = man.get("dtype", "float32")
    metas = {name: _meta_from_json(d) for name, d in man["blocks"].items()}
    eigen: Dict[str, Dict[str, Any]] = {
        name: {k: None for k in _EIG_KEYS} for name in metas}
    diag: Dict[str, Any] = {}
    with np.load(os.path.join(path, "arrays.npz")) as z:
        for key in z.files:
            if key.startswith("eig::"):
                _, name, k = key.split("::", 2)
                eigen[name][k] = _from_store(z[key], k, dtype)
            elif key.startswith("diag::"):
                diag[key[len("diag::"):]] = z[key]
    return CurvatureBundle(
        step=int(man["step"]), lam=float(man["lam"]),
        gamma=float(man["gamma"]), eta=float(man["eta"]),
        metas=metas, eigen=eigen, diag=diag, schema=int(man["schema"]))


# ---------------------------------------------------------------------------
# non-blocking export
# ---------------------------------------------------------------------------

class BundleWriter:
    """Background bundle serializer (one in flight at a time, like the
    Checkpointer's async save).  ``write_async`` returns immediately — the
    snapshot's device arrays are immutable, so the daemon thread can fetch
    and serialize them while training continues."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def write_async(self, path: str, bundle: CurvatureBundle,
                    dtype: str = "float32") -> str:
        self.wait()
        self._thread = threading.Thread(
            target=save_bundle, args=(bundle, path, dtype), daemon=True)
        self._thread.start()
        return path

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
