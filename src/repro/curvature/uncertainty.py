"""Laplace posterior predictive variance for uncertainty-aware decoding.

Last-layer Laplace approximation over the bundle: treat the LM head weights
as Gaussian around the trained values with covariance the *damped inverse
Fisher* of the head block, ``Σ = (F_head + λI)^{-1}``.  For logits
``z = Wᵀh`` the predictive variance of each logit is the quadratic form

    var(z_v) = (h ⊗ e_v)ᵀ Σ (h ⊗ e_v)

which is closed-form in the bundle's eigenbasis — no sampling, no extra
matmuls beyond one ``d×d`` rotation shared across the vocabulary:

* untied head (block ``lm_head``: ``a`` full over d_model, ``g`` diagonal
  over vocab; ``s+damp`` shaped ``(d, V)``): with ``t = Q_Aᵀ h``,

      var(z_v) = Σ_i t_i² / (s + damp)_{i,v}        —  ``(t²) @ M``

* tied embeddings (block ``embed``: ``a`` diagonal over vocab, ``g`` full
  over d_model; ``s+damp`` shaped ``(V, d)``): with ``t = Q_Gᵀ h``,

      var(z_v) = Σ_j t_j² / (s + damp)_{v,j}        —  ``(t²) @ Mᵀ``

Both collapse to one ``(B, d) @ (d, V)`` matmul against the precomputed
reciprocal diagonal ``M`` — the uncertainty pass is a second head, batched
alongside normal decode.  Variances are in units of the damped inverse
empirical Fisher (the bundle's normalization); ``scale`` rescales them if a
calibrated posterior (e.g. ``1/N``) is wanted.  All four reduced LM configs
tie their embeddings, so the tied path is the serving default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.curvature.bundle import CurvatureBundle


class LaplaceHead:
    """Closed-form per-token logit variance from a curvature bundle."""

    def __init__(self, bundle: CurvatureBundle, *, scale: float = 1.0,
                 floor: float = 1e-12):
        name = self._head_block(bundle)
        meta = bundle.metas[name]
        eig = bundle.eigen[name]
        inv_sd = 1.0 / (jnp.asarray(eig["s"], jnp.float32)
                        + jnp.asarray(eig["damp"], jnp.float32) + floor)
        if meta.kind == "head":        # untied: s+damp is (d_model, vocab)
            rot = eig["qa"]
            self.m = inv_sd
        else:                          # tied "embed": s+damp is (vocab, d)
            rot = eig["qg"]
            self.m = inv_sd.T
        self.rot = None if rot is None else jnp.asarray(rot, jnp.float32)
        self.block = name
        self.scale = float(scale)
        self._var = jax.jit(self._variance_impl)

    @staticmethod
    def _head_block(bundle: CurvatureBundle) -> str:
        for name, meta in bundle.metas.items():
            if meta.kind == "head":
                return name
        for name, meta in bundle.metas.items():
            if meta.kind == "embed":
                return name
        raise ValueError(
            "bundle has no head/embed block — cannot build a Laplace head "
            f"(blocks: {sorted(bundle.metas)})")

    @classmethod
    def from_path(cls, path: str, **kw) -> "LaplaceHead":
        from repro.curvature.bundle import load_bundle
        return cls(load_bundle(path), **kw)

    # ------------------------------------------------------------------
    def _variance_impl(self, h):
        t = h.astype(jnp.float32)
        if self.rot is not None:
            t = t @ self.rot           # Qᵀh along the feature axis
        return self.scale * ((t * t) @ self.m)

    def variance(self, h):
        """Per-logit predictive variance: ``(..., d_model) -> (..., vocab)``.

        Traceable (pure jnp) — safe to call inside a jitted decode step."""
        return self._variance_impl(h)

    def __call__(self, h):
        return self._var(h)
