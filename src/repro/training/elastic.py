"""Elastic scaling + straggler mitigation.

Elastic re-mesh: every piece of K-FAC state (params, factors, inverses,
momentum) has a mesh-independent logical layout; moving a job to a different
pod count is `reshard(state, new_mesh_shardings)` after a checkpoint restore
(the data pipeline is (seed, step)-deterministic, so the token stream is
unaffected).

Straggler mitigation in a synchronous SPMD world:
  * the d³ inverse work is amortized (T3) and hot-started (Newton-Schulz) —
    the heavy step is rare and bounded;
  * `KFACConfig.stats_period` / tau1 drop stats work under time pressure;
  * checkpoint-restart excludes persistently slow hosts (the launcher can
    rebuild the mesh without them — see reshard below).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding


def reshard(tree, shardings):
    """device_put every leaf onto new shardings (same tree structure)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, shardings)


def remesh_plan(old_mesh: Mesh, new_mesh: Mesh, specs_tree):
    """Build the sharding tree for `reshard` on the new mesh from the
    PartitionSpec tree used on the old one."""
    return jax.tree.map(lambda spec: NamedSharding(new_mesh, spec),
                        specs_tree)
