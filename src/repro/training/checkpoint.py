"""Native checkpointing: atomic, async, reshard-on-restore.

Layout:  <dir>/step_<N>/
           arrays.npz      — flat {path-key: np.ndarray}
           manifest.json   — step, keys, schema version, scalar metadata
           COMMIT          — written last; absence marks a torn checkpoint

Restore resharding: leaves are device_put against caller-supplied shardings,
so a checkpoint taken on one mesh restores onto any other (elastic scaling).

State-schema versions (``manifest.json["schema"]``):
  1 (implicit — pre-version manifests): optimizer state was a raw dict.
  2: optimizer state is the typed ``KFACState``/``TransformState``
     dataclass.  The dataclass field names match the old dict keys, and
     path keys are name-based (dict key / dataclass attribute / sequence
     index), so v1 checkpoints restore into a v2 dataclass template
     unchanged — that *is* the migration shim, pinned by
     ``tests/test_training.py::test_checkpoint_dict_state_migration``.
  3: ``KFACState`` gained the distributed-refresh fields ``staleness``
     and ``inv_pending`` (refresh_mode="overlap" double buffer).  Older
     checkpoints simply lack those keys; on restore of schema<=2 the
     missing v3 leaves fall back to the caller's template values (fresh
     ``opt.init`` defaults: staleness 0, identity pending buffer) —
     pinned by ``test_checkpoint_v2_state_migration``.  ``inv_pending``
     leaves additionally exist only for overlap-mode runs, so they stay
     defaultable at schema 3 too: restoring a sync-mode checkpoint into
     an overlap template (flipping refresh_mode on an existing run) seeds
     the double buffer from the template — pinned by
     ``test_checkpoint_refresh_mode_switch``.
  4: manifest-only change — an optional ``curvature_bundle`` key pointing
     (relative to the checkpoint directory) at the EKFAC curvature bundle
     exported alongside this step (``repro.curvature.bundle``; bundles
     live in a sibling ``curvature/`` dir, never inside the step dir,
     because the step dir is renamed asynchronously).  No state leaves
     changed, so restore logic is untouched: v3 checkpoints restore
     verbatim (they just have no bundle — ``bundle_path`` returns None),
     pinned by ``test_checkpoint_v3_state_migration``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

SEP = "::"
SCHEMA_VERSION = 4

# state fields that did not exist before schema 3: restoring an older
# checkpoint keeps the template's (fresh-init) values for these
_V3_FIELDS = ("staleness", "inv_pending")
# ... and fields whose *presence* depends on run config, not schema:
# inv_pending only exists in refresh_mode="overlap" states, so a schema-3
# checkpoint written in a sync mode has no such leaves — restoring it into
# an overlap template (switching refresh modes on an existing run) must
# fall back to the template's fresh double buffer instead of hard-failing
_MODE_FIELDS = ("inv_pending",)


def _key_str(k) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (registered
    # dataclasses like KFACState) -> .name: all collapse to the plain
    # field/key name so dict-era and dataclass-era checkpoints share keys
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[SEP.join(_key_str(k) for k in path)] = leaf
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray],
                    defaultable: tuple = ()):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        parts = [_key_str(k) for k in path]
        key = SEP.join(parts)
        if key not in flat:
            if any(p in defaultable for p in parts):
                # schema migration: field added after this checkpoint was
                # written — keep the template's fresh-init value
                leaves.append(leaf)
                continue
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree, block: bool = False,
             curvature_bundle: Optional[str] = None):
        """``curvature_bundle``: optional manifest pointer (schema 4) to a
        bundle exported for this step, as a path relative to ``self.dir``
        (the bundle itself is written separately — see
        ``repro.curvature.bundle.BundleWriter``)."""
        flat = _flatten(tree)
        # fetch to host synchronously (cheap vs I/O), write in background
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, curvature_bundle),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, curvature_bundle)

    def _write(self, step: int, host: Dict[str, np.ndarray],
               curvature_bundle: Optional[str] = None):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in host.items()})
        manifest = {"step": step, "schema": SCHEMA_VERSION,
                    "keys": sorted(host), "time": time.time()}
        if curvature_bundle is not None:
            manifest["curvature_bundle"] = curvature_bundle
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
            # drop the step's curvature bundle (sibling dir) with it
            shutil.rmtree(
                os.path.join(self.dir, "curvature", f"step_{s:08d}"),
                ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def all_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, d)
            if (d.startswith("step_") and not d.endswith(".tmp")
                    and os.path.exists(os.path.join(full, "COMMIT"))):
                out.append(int(d[5:]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def bundle_path(self, step: Optional[int] = None) -> Optional[str]:
        """Absolute path of the curvature bundle the manifest points at
        (schema 4), or None — older schemas, runs without curvature
        export, or a torn/missing bundle all report None."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        man = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        if not os.path.exists(man):
            return None
        with open(man) as f:
            rel = json.load(f).get("curvature_bundle")
        if rel is None:
            return None
        full = os.path.join(self.dir, rel)
        if not os.path.exists(os.path.join(full, "COMMIT")):
            return None
        return full

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Rebuild `template`-shaped tree; leaves device_put to `shardings`
        (same-structure tree of NamedShardings) when given — this is the
        elastic re-mesh path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        man_path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(man_path) as f:
            schema = json.load(f).get("schema", 1)
        if schema > SCHEMA_VERSION:
            raise ValueError(f"checkpoint at step {step} has schema "
                             f"{schema} > supported {SCHEMA_VERSION}")
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(
            template, flat,
            defaultable=_V3_FIELDS if schema < 3 else _MODE_FIELDS)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else
                jax.numpy.asarray(x), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return step, tree
