"""Fault-tolerant K-FAC training loop.

Schedule (paper Algorithm 2): stats+grads every step; inverses every T3
steps and for k<=3; gamma candidate sweep every T2; lambda rule every T1.

Fault tolerance:
  * atomic async checkpoints every `checkpoint_every` (params + full
    optimizer state + step), auto-restore on construction;
  * SIGTERM/SIGINT preemption hook → synchronous checkpoint, clean exit;
  * non-finite guard: a NaN/Inf update is *skipped* (params untouched,
    damping raised) rather than poisoning the run;
  * elastic restart: checkpoints restore onto any mesh (see elastic.py).
"""
from __future__ import annotations

import signal
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KFACConfig, TrainConfig
from repro.core.kfac import KFAC
from repro.training.checkpoint import Checkpointer
from repro.utils import tree as T


class Trainer:
    def __init__(self, model, opt: KFAC, train_cfg: TrainConfig, mesh=None,
                 checkpointer: Optional[Checkpointer] = None):
        self.model = model
        self.opt = opt
        self.tc = train_cfg
        self.mesh = mesh
        self.ckpt = checkpointer
        self._preempted = False
        self._install_handlers()

        self._stats = jax.jit(opt.stats_grads)
        self._grads_only = jax.jit(opt.grads_only)
        self._rescale = jax.jit(opt.rescale_step) if opt.cfg.inv_mode == \
            "eigen" else None
        self._refresh = jax.jit(lambda s: opt.refresh_inverses(s, hot=True))
        self._stagger = opt.stagger_groups()
        self._refresh_sub = {
            i: jax.jit(lambda s, ns=tuple(g): opt.refresh_subset(s, ns))
            for i, g in enumerate(self._stagger)} if opt.cfg.staggered_inverse \
            else None
        self._update = jax.jit(
            lambda s, p, g, b, r: opt.apply_update(s, p, g, b, r))
        self._multi = jax.jit(opt.refresh_multi)
        self._update3 = jax.jit(
            lambda s, p, g, b, r, gs, i3: opt.apply_update(
                s, p, g, b, r,
                cand_inv=[jax.tree.map(lambda x: x[c], i3) for c in range(3)],
                gammas=gs))
        self._lambda = jax.jit(opt.lambda_step)

    # ------------------------------------------------------------------
    def _install_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    # ------------------------------------------------------------------
    def fit(self, params, data, steps: int, start_step: int = 0,
            log=print) -> Dict[str, Any]:
        cfg = self.opt.cfg
        if cfg.kernel_backend != "xla":
            log(f"[trainer] curvature blocks on kernel_backend="
                f"{cfg.kernel_backend} (interpret="
                f"{jax.default_backend() != 'tpu'})")
        batch0 = data.batch(start_step)
        state = self.opt.init(params, batch0)

        # auto-restore
        if self.ckpt is not None:
            got_step, got = self.ckpt.restore({"params": params,
                                               "state": state})
            if got_step is not None:
                params, state = got["params"], got["state"]
                start_step = got_step
                log(f"[trainer] restored checkpoint at step {got_step}")

        history = []
        t_start = time.time()
        for step in range(start_step, steps):
            batch = data.batch(step)
            rng = jax.random.fold_in(jax.random.PRNGKey(self.tc.seed), step)

            if step % cfg.stats_period == 0:
                state, grads, metrics = self._stats(state, params, batch, rng)
            else:
                # stats skipped (straggler/budget mode): grads only
                state, grads, metrics = self._grads_only(state, params, batch,
                                                         rng)

            use_gamma_sweep = (cfg.t2 > 0 and step > 0 and step % cfg.t2 == 0)
            if use_gamma_sweep:
                gs, i3 = self._multi(state)
                new_params, state, um = self._update3(
                    state, params, grads, batch, rng, gs, i3)
            else:
                if step - start_step < 3:
                    state = self._refresh(state)
                elif self._refresh_sub is not None:
                    # staggered: 1/T3 of the layer inverses per step
                    state = self._refresh_sub[step % cfg.t3](state)
                elif step % cfg.t3 == 0:
                    state = self._refresh(state)
                if self._rescale is not None:
                    # eigen mode: per-step EKFAC diagonal re-estimation in
                    # the (amortized) eigenbases
                    state = self._rescale(state, grads)
                new_params, state, um = self._update(
                    state, params, grads, batch, rng)

            # non-finite guard: skip poisoned updates, raise damping
            finite = bool(T.tree_isfinite(new_params)) and np.isfinite(
                float(um["delta_norm"]))
            if finite:
                params = new_params
            else:
                state = dict(state, lam=state["lam"] * 4.0,
                             delta0=T.tree_zeros_like(state["delta0"]))
                log(f"[trainer] step {step}: non-finite update SKIPPED "
                    f"(lam -> {float(state['lam']):.3g})")

            if cfg.t1 > 0 and (step + 1) % cfg.t1 == 0:
                state, rho = self._lambda(state, params, batch, rng)

            metrics = {**metrics, **um}
            history.append({k: float(v) for k, v in metrics.items()
                            if jnp.ndim(v) == 0})
            if step % self.tc.log_every == 0:
                log(f"[trainer] step {step}: loss={history[-1]['loss']:.4f} "
                    f"alpha={history[-1]['alpha']:.2e} "
                    f"lam={float(state['lam']):.3g}")

            if self.ckpt is not None and (
                    (step + 1) % self.tc.checkpoint_every == 0):
                self.ckpt.save(step + 1, {"params": params, "state": state})

            if self._preempted:
                log(f"[trainer] preempted at step {step}; checkpointing")
                if self.ckpt is not None:
                    self.ckpt.save(step + 1, {"params": params,
                                              "state": state}, block=True)
                break

        if self.ckpt is not None:
            self.ckpt.wait()
        return {"params": params, "state": state, "history": history,
                "seconds": time.time() - t_start}
