"""Fault-tolerant, optimizer-agnostic training loop.

The trainer knows nothing about any particular optimizer: per step it
calls ``opt.update(None, state, params, batch, rng)`` and lets the
optimizer run its own schedule (for K-FAC that is paper Algorithm 2 —
stats+grads every step, inverses every T3 and for k<=3, gamma sweep every
T2, lambda rule every T1 — all driven off the step counter in the state by
``repro.optimizers.kfac.KFACPipeline``).  Any
:class:`repro.core.transform.Optimizer` races through the same loop;
legacy ``repro.core.kfac.KFAC`` engines are wrapped automatically.

Fault tolerance:
  * atomic async checkpoints every `checkpoint_every` (params + full
    optimizer state + step), auto-restore on construction;
  * SIGTERM/SIGINT preemption hook → synchronous checkpoint, clean exit;
  * non-finite guard: a NaN/Inf update is *skipped* (params untouched,
    ``opt.reject`` applied — K-FAC raises damping and clears momentum)
    rather than poisoning the run;
  * elastic restart: checkpoints restore onto any mesh (see elastic.py).
"""
from __future__ import annotations

import signal
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.optimizers import as_optimizer
from repro.training.checkpoint import Checkpointer
from repro.utils import tree as T


class Trainer:
    def __init__(self, model, opt, train_cfg: TrainConfig, mesh=None,
                 checkpointer: Optional[Checkpointer] = None, obs=None):
        from repro import obs as obs_mod
        self.model = model
        self.opt = as_optimizer(opt)
        self.tc = train_cfg
        self.mesh = mesh
        self.ckpt = checkpointer
        # telemetry (repro.obs): obs=None reads train_cfg.obs; launchers
        # pass the same Obs they handed the optimizer so train_step and
        # kfac_step events land in one log.  Counters stay live even when
        # disabled (cheap host ints); timing/events only when enabled.
        self.obs = obs_mod.from_config(obs if obs is not None
                                       else train_cfg.obs)
        self._c_rejected = self.obs.counter("train/rejected_steps")
        self._c_steps = self.obs.counter("train/steps")
        self._preempted = False
        self._bundle_writer = None
        self._install_handlers()

    # ------------------------------------------------------------------
    def _install_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    # ------------------------------------------------------------------
    def fit(self, params, data, steps: int, start_step: int = 0,
            log=print) -> Dict[str, Any]:
        batch0 = data.batch(start_step)
        state = self.opt.init(params, batch0)

        # auto-restore
        if self.ckpt is not None:
            got_step, got = self.ckpt.restore({"params": params,
                                               "state": state})
            if got_step is not None:
                params, state = got["params"], got["state"]
                start_step = got_step
                log(f"[trainer] restored checkpoint at step {got_step}")

        history = []
        t_start = time.time()
        fused = bool(getattr(getattr(self.opt, "engine", None),
                             "fused", False))
        for step in range(start_step, steps):
            batch = data.batch(step)
            rng = jax.random.fold_in(jax.random.PRNGKey(self.tc.seed), step)

            # per-step wall time: host-side span blocking on the produced
            # params at close (enabled only — disabled is the shared no-op
            # span: no clock reads, no extra sync, same jitted programs)
            with self.obs.span("train/step",
                               block=lambda: new_params) as span:
                new_params, state, metrics = self.opt.update(
                    None, state, params, batch, rng)

            # non-finite guard: skip poisoned updates, let the optimizer
            # react (K-FAC: 4x damping + momentum reset)
            finite = bool(T.tree_isfinite(new_params)) and np.isfinite(
                float(metrics.get("delta_norm", 0.0)))
            self._c_steps.inc()
            if finite:
                params = new_params
            else:
                state = self.opt.reject(state)
                self._c_rejected.inc()
                log(f"[trainer] step {step}: non-finite update SKIPPED "
                    f"(rejected by {self.opt.name})")

            # swap hook: optimizers running asynchronous side computations
            # (K-FAC refresh_mode="overlap") commit any finished buffer
            # here without blocking the step loop
            if self.opt.poll is not None:
                state = self.opt.poll(state)

            history.append({k: float(v) for k, v in metrics.items()
                            if jnp.ndim(v) == 0})
            if self.obs.enabled:
                self._emit_step(step, span.seconds, history[-1],
                                rejected=not finite, fused=fused)
            if step % self.tc.log_every == 0:
                extras = " ".join(
                    f"{k}={history[-1][k]:.2e}" for k in ("alpha", "lam")
                    if k in history[-1])
                log(f"[trainer] step {step}: "
                    f"loss={history[-1]['loss']:.4f} {extras}".rstrip())

            if self.ckpt is not None and (
                    (step + 1) % self.tc.checkpoint_every == 0):
                bundle_ref = self._export_bundle(step + 1, state, log)
                self.ckpt.save(step + 1, {"params": params, "state": state},
                               curvature_bundle=bundle_ref)

            if self._preempted:
                log(f"[trainer] preempted at step {step}; checkpointing")
                if self.ckpt is not None:
                    self.ckpt.save(step + 1, {"params": params,
                                              "state": state}, block=True)
                break

        if self.ckpt is not None:
            self.ckpt.wait()
        if self._bundle_writer is not None:
            self._bundle_writer.wait()
        return {"params": params, "state": state, "history": history,
                "seconds": time.time() - t_start}

    # ------------------------------------------------------------------
    def _emit_step(self, step: int, wall_s, hist_row: dict, *,
                   rejected: bool, fused: bool):
        """One ``train_step`` JSONL event + gauges (enabled path only).
        The optimizer's scalar metrics ride along under their own names
        (lam / gamma / alpha / rho / nu / staleness when present)."""
        def fin(x):      # a rejected step's metrics may be NaN/Inf; the
            return float(x) if np.isfinite(x) else None   # schema is finite-only
        extras = {k: fin(hist_row[k])
                  for k in ("lam", "gamma", "alpha", "rho", "nu",
                            "staleness", "grad_norm", "delta_norm")
                  if k in hist_row}
        self.obs.emit("train_step", step=step,
                      loss=fin(hist_row.get("loss", 0.0)),
                      wall_s=wall_s, rejected=rejected,
                      fused_stats=fused, **extras)
        self.obs.gauge("train/loss").set(hist_row.get("loss", 0.0))
        if "lam" in hist_row:
            self.obs.gauge("train/lambda").set(hist_row["lam"])
        if "gamma" in hist_row:
            self.obs.gauge("train/gamma").set(hist_row["gamma"])
        self.obs.maybe_console(step, title="train")

    # ------------------------------------------------------------------
    def _export_bundle(self, step: int, state, log) -> Optional[str]:
        """Non-blocking curvature-bundle export at checkpoint steps
        (``TrainConfig.curvature_every``; 0 = off).  Snapshotting only
        captures immutable device-array references on the training thread
        (the ``OverlapController`` idea); serialization runs on the
        :class:`~repro.curvature.bundle.BundleWriter` daemon thread.
        Returns the manifest-relative bundle path, or None."""
        import os

        if (not self.tc.curvature_every
                or step % self.tc.curvature_every != 0):
            return None
        engine = getattr(self.opt, "engine", None)
        if engine is None or not getattr(engine, "blocks", None):
            return None   # first-order baselines carry no curvature
        from repro.curvature.bundle import BundleWriter, snapshot_bundle

        opt_state = state.inner if hasattr(state, "inner") else state
        bundle = snapshot_bundle(engine, opt_state)
        if bundle is None:
            return None
        if self._bundle_writer is None:
            self._bundle_writer = BundleWriter()
        rel = os.path.join("curvature", f"step_{step:08d}")
        self._bundle_writer.write_async(
            os.path.join(self.ckpt.dir, rel), bundle)
        log(f"[trainer] step {step - 1}: curvature bundle -> {rel}")
        return rel
