"""Shared request-latency definitions: TTFT vs decode-gap.

There is exactly one definition of the serving latency split, used by
*both* the live engine telemetry and ``benchmarks/bench_serving.py`` —
so the bench rows and the live metrics can never diverge:

* **TTFT** — a request's *first* emission measures submission -> first
  token, i.e. queueing + prefill;
* **decode gap** — every subsequent emission measures the wall-clock gap
  since the request's previous emission (steady-state decode-step
  latency).

A preempted request keeps its TTFT (it already emitted once); its replay
emissions keep counting as decode gaps — preemption pressure shows up in
the decode tail, exactly as the bench always measured it.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import Registry, percentile


class RequestLatencyTracker:
    """Per-request submission/emission clocking.

    Host-side only; optionally mirrors observations into ``registry``
    histograms ``serve/ttft_ms`` and ``serve/decode_gap_ms``."""

    def __init__(self, registry: Optional[Registry] = None):
        self._reg = registry
        self._h_ttft = (registry.histogram("serve/ttft_ms")
                        if registry else None)
        self._h_dec = (registry.histogram("serve/decode_gap_ms")
                       if registry else None)
        self.reset()

    def reset(self) -> None:
        self._last: Dict[int, float] = {}   # uid -> previous emission time
        self.ttft: Dict[int, float] = {}    # uid -> seconds
        self.decode: Dict[int, List[float]] = {}

    # ------------------------------------------------------------------
    def on_submit(self, uid: int, t: Optional[float] = None) -> None:
        self._last[uid] = time.time() if t is None else t

    def on_emit(self, uid: int, t: Optional[float] = None
                ) -> Tuple[str, float]:
        """Record one token emission; returns ("ttft"|"decode", gap_s)."""
        t = time.time() if t is None else t
        prev = self._last.get(uid)
        if prev is None:
            raise ValueError(f"emission for uid={uid} before on_submit")
        gap = t - prev
        self._last[uid] = t
        if uid not in self.ttft:
            self.ttft[uid] = gap
            if self._h_ttft is not None:
                self._h_ttft.observe(gap * 1e3)
            return "ttft", gap
        self.decode.setdefault(uid, []).append(gap)
        if self._h_dec is not None:
            self._h_dec.observe(gap * 1e3)
        return "decode", gap

    # ------------------------------------------------------------------
    @property
    def ttft_s(self) -> List[float]:
        return list(self.ttft.values())

    @property
    def decode_s(self) -> List[float]:
        return [g for gaps in self.decode.values() for g in gaps]

    @property
    def n_tokens(self) -> int:
        return len(self.ttft) + len(self.decode_s)

    def percentiles(self) -> dict:
        """The four serving-row fields of the BENCH_serving.json schema
        (ms); NaN-free — raises if either distribution is empty."""
        ttft_ms = [x * 1e3 for x in self.ttft_s]
        dec_ms = [x * 1e3 for x in self.decode_s]
        return {
            "ttft_p50_ms": percentile(ttft_ms, 50),
            "ttft_p99_ms": percentile(ttft_ms, 99),
            "decode_p50_ms": percentile(dec_ms, 50),
            "decode_p99_ms": percentile(dec_ms, 99),
        }

    def percentiles_or_none(self) -> dict:
        """Lenient variant for live reports: a missing distribution (no
        requests, or single-token outputs with no decode gaps) yields
        ``None`` entries instead of raising."""
        ttft_ms = [x * 1e3 for x in self.ttft_s]
        dec_ms = [x * 1e3 for x in self.decode_s]
        return {
            "ttft_p50_ms": percentile(ttft_ms, 50) if ttft_ms else None,
            "ttft_p99_ms": percentile(ttft_ms, 99) if ttft_ms else None,
            "decode_p50_ms": percentile(dec_ms, 50) if dec_ms else None,
            "decode_p99_ms": percentile(dec_ms, 99) if dec_ms else None,
        }
