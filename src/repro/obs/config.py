"""ObsConfig: the one switch every plane's telemetry rides behind.

Kept dependency-free (no jax, no registry imports) so
``repro.configs.base`` can embed it in the frozen ``TrainConfig`` /
``KFACConfig`` dataclasses without import cycles.

The contract (docs/observability.md):

* ``enabled=False`` (the default) must be bitwise-identical to an
  uninstrumented program — same jitted functions, no extra host syncs,
  no timing syscalls on the hot path.  Counters still count (they are
  plain host integers and feed ``RunReport``-style summaries), but spans
  are no-op context managers and no sink I/O happens.
* ``enabled=True`` buys wall-clock spans (device work timed host-side
  after ``block_until_ready`` at span close — never via callbacks inside
  jit), the JSONL event sink, and the periodic console summary, at a
  measured few-percent overhead (the ``obs_overhead`` row in
  ``BENCH_optimizer.json``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ObsConfig:
    enabled: bool = False
    jsonl_path: str = ""            # append-only event sink ("" = none)
    console_every: int = 0          # steps between console summaries (0 = off)
    trace_annotations: bool = False  # wrap spans in jax.profiler
                                     # TraceAnnotation so they show up in
                                     # TensorBoard / perfetto profiles
    reservoir: int = 2048           # histogram sample bound: percentiles are
                                     # exact over the most recent this-many
                                     # observations

    def replace(self, **kw) -> "ObsConfig":
        return dataclasses.replace(self, **kw)
