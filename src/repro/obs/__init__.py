"""repro.obs — unified telemetry: metrics registry, spans, exporters.

One lightweight subsystem observes all three planes (training,
distributed refresh, serving):

* :class:`~repro.obs.metrics.Registry` — typed ``Counter`` / ``Gauge`` /
  ``Histogram`` instruments with labels, thread-safe, exact p50/p99 over
  a bounded reservoir;
* :mod:`~repro.obs.tracing` — trace-safe spans (device work timed
  host-side after ``block_until_ready`` at span close, never via
  callbacks inside jit; optional ``jax.profiler.TraceAnnotation``
  pass-through);
* :mod:`~repro.obs.export` — append-only schema-versioned JSONL event
  sink, Prometheus text snapshot, console summarizer (the one formatting
  path the launchers render from);
* :mod:`~repro.obs.latency` — the shared TTFT / decode-gap definitions
  (live engine telemetry and ``bench_serving`` use the same class).

Everything rides behind :class:`ObsConfig` (threaded through
``TrainConfig`` / ``KFACConfig`` / the serving-engine constructor).  The
facade is :class:`Obs`: counters/gauges always count (plain host
integers — they feed ``RunReport``-style summaries even when disabled),
while *timing* (spans, sync points), the JSONL sink and the console
summary exist only when ``enabled=True`` — the disabled program is
bitwise-identical to an uninstrumented one, with the same jitted
functions and no extra host syncs (pinned by ``tests/test_obs.py``).
See ``docs/observability.md`` for the metric catalog.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

from repro.obs.config import ObsConfig
from repro.obs.export import (JsonlSink, console_summary, prometheus_text,
                              read_jsonl, validate_event, SCHEMA_VERSION)
from repro.obs.latency import RequestLatencyTracker
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               percentile)
from repro.obs.tracing import NULL_SPAN, NullSpan, Span

__all__ = [
    "Obs", "ObsConfig", "from_config",
    "Counter", "Gauge", "Histogram", "Registry", "percentile",
    "Span", "NullSpan", "NULL_SPAN",
    "JsonlSink", "console_summary", "prometheus_text", "read_jsonl",
    "validate_event", "SCHEMA_VERSION",
    "RequestLatencyTracker",
]


class Obs:
    """Facade: one registry + (when enabled) one JSONL sink + console.

    Share a single ``Obs`` across planes (trainer, optimizer pipeline,
    serving engine) to land their events in one log file; the launchers
    do exactly that."""

    def __init__(self, config: Optional[ObsConfig] = None,
                 registry: Optional[Registry] = None):
        self.config = config if config is not None else ObsConfig()
        self.registry = registry if registry is not None else Registry(
            self.config.reservoir)
        self.sink = (JsonlSink(self.config.jsonl_path)
                     if self.config.enabled and self.config.jsonl_path
                     else None)

    @property
    def enabled(self) -> bool:
        return self.config.enabled

    # -- instruments (always live: cheap host counters) ----------------
    def counter(self, name: str, labels=None) -> Counter:
        return self.registry.counter(name, labels)

    def gauge(self, name: str, labels=None) -> Gauge:
        return self.registry.gauge(name, labels)

    def histogram(self, name: str, labels=None) -> Histogram:
        return self.registry.histogram(name, labels)

    # -- timing (enabled only) -----------------------------------------
    def span(self, name: str,
             block: Union[None, Callable, object] = None
             ) -> Union[Span, NullSpan]:
        """Trace-safe span: wall seconds recorded into the
        ``span_s{span=<name>}`` histogram at close, after blocking on
        ``block``.  The disabled path is a shared no-op context manager
        (no clock reads, no blocking)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(name, self.registry.histogram("span_s", {"span": name}),
                    block=block, annotate=self.config.trace_annotations)

    # -- events (enabled only) -----------------------------------------
    def emit(self, event: str, **payload) -> None:
        if self.sink is not None:
            self.sink.write(event, payload)

    def maybe_console(self, step: int, title: str = "obs") -> None:
        every = self.config.console_every
        if self.enabled and every > 0 and step % every == 0:
            print(self.summary(title))

    def summary(self, title: str = "obs") -> str:
        return console_summary(self.registry, title)

    def prometheus(self) -> str:
        return prometheus_text(self.registry)

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()


def from_config(cfg: Union[None, ObsConfig, Obs]) -> Obs:
    """Coerce an ObsConfig (or None, or an existing Obs) into an Obs."""
    if isinstance(cfg, Obs):
        return cfg
    return Obs(cfg)
