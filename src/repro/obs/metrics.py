"""Typed metrics registry: Counter / Gauge / Histogram with labels.

Thread-safe by construction — the serving engine, the ``BundleWriter``
and the ``OverlapController`` all touch metrics from daemon threads, so
every mutation takes the owning registry's lock.  The primitives are
deliberately dumb host-side objects: never called from inside a jitted
function (tracing discipline lives in ``obs.tracing``).

Histogram percentiles are *exact* over a bounded reservoir: the most
recent ``bound`` observations are kept verbatim (a sliding window, not a
sampling sketch) and ``percentile`` reproduces ``numpy.percentile``'s
default linear interpolation over that window bit-for-bit — pinned
against the numpy reference in ``tests/test_obs.py``.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Dict[str, str]]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def percentile(samples: Iterable[float], q: float) -> float:
    """``numpy.percentile(..., method="linear")`` without numpy: sorted
    rank ``q/100 * (n-1)``, linearly interpolated between neighbors."""
    xs = sorted(float(x) for x in samples)
    if not xs:
        raise ValueError("percentile of empty sample set")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q={q} outside [0, 100]")
    rank = q / 100.0 * (len(xs) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return xs[int(rank)]
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


class Metric:
    """Base: a named instrument bound to one label set in one registry."""

    kind = "untyped"

    def __init__(self, name: str, labels: LabelKey, lock: threading.Lock):
        self.name = name
        self.labels = labels
        self._lock = lock


class Counter(Metric):
    """Monotonic count (events, tokens, rejected steps, ...)."""

    kind = "counter"

    def __init__(self, name, labels, lock):
        super().__init__(name, labels, lock)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) < 0")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(Metric):
    """Last-write-wins level (queue depth, staleness, lambda, ...)."""

    kind = "gauge"

    def __init__(self, name, labels, lock):
        super().__init__(name, labels, lock)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(Metric):
    """Count/sum/min/max plus a bounded reservoir of the most recent
    ``bound`` observations; ``percentile`` is exact over the window."""

    kind = "histogram"

    def __init__(self, name, labels, lock, bound: int = 2048):
        super().__init__(name, labels, lock)
        self.bound = max(1, int(bound))
        self._window: deque = deque(maxlen=self.bound)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._window.append(v)
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        with self._lock:
            return percentile(self._window, q)

    def snapshot(self) -> dict:
        with self._lock:
            window = list(self._window)
        out = {"count": self._count, "sum": self._sum,
               "mean": (self._sum / self._count if self._count else 0.0)}
        if window:
            out["min"] = self._min
            out["max"] = self._max
            out["p50"] = percentile(window, 50)
            out["p99"] = percentile(window, 99)
        return out


class Registry:
    """Get-or-create instrument store keyed by (name, labels).

    One lock guards both the instrument table and every instrument's
    mutations — contention is negligible at telemetry rates and the
    single lock keeps snapshot consistency trivial."""

    def __init__(self, reservoir: int = 2048):
        self.reservoir = reservoir
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelKey], Metric] = {}

    def _get(self, cls, name: str, labels, **kw) -> Metric:
        # keyed by (name, labels) — one name maps to ONE kind, as the
        # Prometheus exposition format requires; asking for the same name
        # as a different kind is a bug, not a new instrument
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], self._lock, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None
                ) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None
              ) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None,
                  bound: Optional[int] = None) -> Histogram:
        return self._get(Histogram, name, labels,
                         bound=bound or self.reservoir)

    # ------------------------------------------------------------------
    def metrics(self) -> List[Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def find(self, name: str, kind: Optional[str] = None) -> List[Metric]:
        """Every instrument registered under ``name`` (any label set)."""
        return [m for m in self.metrics()
                if m.name == name and (kind is None or m.kind == kind)]

    def snapshot(self) -> dict:
        """Plain-data view: {kind: {name{labels}: value-or-stats}}."""
        out: Dict[str, dict] = {"counter": {}, "gauge": {}, "histogram": {}}
        for m in self.metrics():
            label_s = ",".join(f"{k}={v}" for k, v in m.labels)
            key = f"{m.name}{{{label_s}}}" if label_s else m.name
            if isinstance(m, Histogram):
                out["histogram"][key] = m.snapshot()
            else:
                out[m.kind][key] = m.value
        return out
