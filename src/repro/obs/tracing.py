"""Trace-safe span timing for JAX programs.

The one rule: device work is timed **host-side**, by blocking on the
span's declared outputs at span *close* (``jax.block_until_ready``) —
never via callbacks inside a jitted function.  A span therefore measures
dispatch + device execution of whatever pytree you hand it, and the
jitted program itself is untouched (spans never appear in the HLO, so
disabled-vs-enabled programs are identical; only the host's sync points
differ).

    with obs.span("refresh/eigh", block=lambda: state.inv):
        state = refresh(state)

``block`` may be a pytree or a zero-arg callable evaluated at exit (use
the callable form when the arrays are produced inside the ``with``
body).  With ``ObsConfig.trace_annotations`` the span also enters a
``jax.profiler.TraceAnnotation``, so the same names line up in
TensorBoard / perfetto device profiles.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Union

from repro.obs.metrics import Histogram


class Span:
    """Context manager: wall seconds from enter to (blocked) exit,
    recorded into ``hist`` and readable as ``.seconds`` afterwards."""

    def __init__(self, name: str, hist: Optional[Histogram] = None,
                 block: Union[None, Callable, object] = None,
                 annotate: bool = False):
        self.name = name
        self.hist = hist
        self.block = block
        self.seconds: Optional[float] = None
        self._annotation = None
        if annotate:
            import jax
            self._annotation = jax.profiler.TraceAnnotation(name)

    def __enter__(self):
        if self._annotation is not None:
            self._annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None and self.block is not None:
            import jax
            tree = self.block() if callable(self.block) else self.block
            if tree is not None:
                jax.block_until_ready(tree)
        self.seconds = time.perf_counter() - self._t0
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        if exc_type is None and self.hist is not None:
            self.hist.observe(self.seconds)
        return False


class NullSpan:
    """The disabled path: no clock reads, no blocking, no recording."""

    name = ""
    seconds = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = NullSpan()
