"""Exporters: JSONL event sink, Prometheus text snapshot, console summary.

JSONL event schema (version ``SCHEMA_VERSION``)
-----------------------------------------------
One JSON object per line.  Every event carries::

    {"v": 1, "event": "<type>", "ts": <unix seconds>, ...}

Known event types and their required fields (``EVENT_FIELDS``):

* ``train_step``    — ``step``, ``loss``, ``wall_s`` (+ lam/gamma/alpha/
  rho/nu/staleness/rejected/fused_stats when applicable)
* ``kfac_step``     — ``step``, ``stages`` ({stage name: seconds})
* ``refresh``       — ``mode``, ``wall_s`` (+ plan cost / shard info /
  forced / cancelled for the distributed modes)
* ``serve_request`` — ``uid``, ``n_tokens`` (+ ttft_ms / decode gap
  stats / preemptions)
* ``serve_run``     — ``steps`` (+ completed / preemptions / evictions /
  latency percentiles)

Unknown event types are allowed (forward compatibility) but must still
carry ``v``/``event``/``ts`` and only finite numbers.
``benchmarks/obs_check.py`` is the CI gate over a written log file;
``validate_event`` here is the single source of truth it calls.

The sink appends each line with one ``os.write`` on an ``O_APPEND`` fd,
so concurrent writers (trainer thread + controller daemon, or two ``Obs``
instances pointed at one path) never interleave partial lines.
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from typing import Dict, Optional

from repro.obs.metrics import Histogram, Registry

SCHEMA_VERSION = 1

EVENT_FIELDS: Dict[str, tuple] = {
    "train_step": ("step", "loss", "wall_s"),
    "kfac_step": ("step", "stages"),
    "refresh": ("mode", "wall_s"),
    "serve_request": ("uid", "n_tokens"),
    "serve_run": ("steps",),
}


def _check_finite(obj, path: str) -> None:
    if isinstance(obj, bool) or obj is None or isinstance(obj, (str, int)):
        return
    if isinstance(obj, float):
        if not math.isfinite(obj):
            raise ValueError(f"non-finite number at {path}: {obj!r}")
        return
    if isinstance(obj, dict):
        for k, v in obj.items():
            _check_finite(v, f"{path}.{k}")
        return
    if isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _check_finite(v, f"{path}[{i}]")
        return
    raise ValueError(f"unserializable value at {path}: {type(obj).__name__}")


def validate_event(obj) -> dict:
    """Raise ValueError unless ``obj`` is a schema-valid event dict."""
    if not isinstance(obj, dict):
        raise ValueError(f"event is {type(obj).__name__}, not dict")
    if obj.get("v") != SCHEMA_VERSION:
        raise ValueError(f"event schema v={obj.get('v')!r}, "
                         f"expected {SCHEMA_VERSION}")
    ev = obj.get("event")
    if not isinstance(ev, str) or not ev:
        raise ValueError("event has no 'event' type string")
    ts = obj.get("ts")
    if not isinstance(ts, (int, float)) or not math.isfinite(ts):
        raise ValueError(f"event {ev!r}: ts={ts!r} is not a finite time")
    for field in EVENT_FIELDS.get(ev, ()):
        if field not in obj:
            raise ValueError(f"event {ev!r} missing required field "
                             f"{field!r}")
    _check_finite(obj, ev)
    return obj


class JsonlSink:
    """Append-only JSONL writer (atomic whole-line appends)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                           0o644)
        self._lock = threading.Lock()

    def write(self, event: str, payload: dict) -> dict:
        obj = {"v": SCHEMA_VERSION, "event": event,
               "ts": time.time(), **payload}
        line = json.dumps(obj, sort_keys=False, allow_nan=False) + "\n"
        with self._lock:
            if self._fd is not None:
                os.write(self._fd, line.encode())
        return obj

    def close(self) -> None:
        with self._lock:
            if self._fd is not None:
                os.close(self._fd)
                self._fd = None


def read_jsonl(path: str) -> list:
    """Parse + validate every event in a JSONL log; raises on any bad
    line (with its line number)."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(validate_event(json.loads(line)))
            except ValueError as e:
                raise ValueError(f"{path}:{i}: {e}") from e
    return out


# ---------------------------------------------------------------------------
# Prometheus text snapshot
# ---------------------------------------------------------------------------

def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return f"repro_{out}"


def _prom_labels(labels) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def prometheus_text(registry: Registry) -> str:
    """Prometheus exposition-format snapshot of the whole registry.
    Histograms export as summary-style count/sum plus p50/p99 gauges
    (quantiles over the bounded reservoir)."""
    lines = []
    seen_types = set()
    for m in registry.metrics():
        pname = _prom_name(m.name)
        labs = _prom_labels(m.labels)
        if isinstance(m, Histogram):
            if pname not in seen_types:
                lines.append(f"# TYPE {pname} summary")
                seen_types.add(pname)
            snap = m.snapshot()
            lines.append(f"{pname}_count{labs} {snap['count']}")
            lines.append(f"{pname}_sum{labs} {snap['sum']}")
            for q, key in ((0.5, "p50"), (0.99, "p99")):
                if key in snap:
                    qlabs = list(m.labels) + [("quantile", str(q))]
                    lines.append(f"{pname}{_prom_labels(qlabs)} {snap[key]}")
        else:
            if pname not in seen_types:
                lines.append(f"# TYPE {pname} {m.kind}")
                seen_types.add(pname)
            lines.append(f"{pname}{labs} {m.value}")
    return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Console summary — the ONE formatting path both launchers render from
# ---------------------------------------------------------------------------

def console_summary(registry: Registry, title: str = "obs") -> str:
    """Human-readable snapshot: counters, gauges, then histogram stats.
    ``launch/train.py`` and ``launch/serve.py`` print exactly this — the
    ad-hoc per-launcher stat lines are gone."""
    snap = registry.snapshot()
    lines = [f"[{title}] --- telemetry snapshot ---"]
    for key, val in snap["counter"].items():
        lines.append(f"[{title}] {key} = {val:g}")
    for key, val in snap["gauge"].items():
        lines.append(f"[{title}] {key} = {val:g}")
    for key, st in snap["histogram"].items():
        if st["count"] == 0:
            continue
        lines.append(
            f"[{title}] {key}: n={st['count']} mean={st['mean']:.4g}"
            + (f" p50={st['p50']:.4g} p99={st['p99']:.4g}"
               f" max={st['max']:.4g}" if "p50" in st else ""))
    return "\n".join(lines)
