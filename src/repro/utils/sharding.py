"""Mesh-axis helpers.

Physical mesh axes: ``("data", "model")`` single-pod or
``("pod", "data", "model")`` multi-pod.  Batch is sharded over
``(pod, data)``; tensor-parallel dims over ``model``; FSDP parameter
storage over ``data`` (all-gather happens inside the pod over ICI, while the
``pod`` axis only carries gradient/statistic reductions over DCN).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Optional[Mesh]):
    """The mesh axes the global batch is sharded over."""
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def spec_for_batch(mesh, *trailing):
    return P(batch_axes(mesh), *trailing)


def named(mesh: Optional[Mesh], spec: P):
    if mesh is None:
        return None
    return NamedSharding(mesh, spec)


def constrain(x, mesh: Optional[Mesh], spec: P):
    """with_sharding_constraint that no-ops without a mesh (CPU tests)."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def pick_shard(dim: int, mesh: Optional[Mesh], axis: str) -> Optional[str]:
    """Return `axis` if `dim` is divisible by that mesh axis size, else None.

    Keeps specs valid for reduced smoke-test configs on 1 device and for dims
    (e.g. 8 kv heads on a 16-way model axis) that don't divide evenly.
    """
    if mesh is None or axis not in mesh.axis_names:
        return None
    return axis if divides(dim, mesh.shape[axis]) else None


def axis_size(mesh: Optional[Mesh], axis) -> int:
    if mesh is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= axis_size(mesh, a)
        return n
    return mesh.shape.get(axis, 1) if axis in mesh.axis_names else 1
