"""Pytree arithmetic helpers (no optax offline — we roll our own)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    """Sum of elementwise products across the whole pytree (float32 accum)."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sqnorm(a):
    return tree_dot(a, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a)


def tree_count_params(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def get_path(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def set_path(tree, path, value):
    """Functionally replace tree[path] (dicts/tuples/lists only)."""
    if not path:
        return value
    k, rest = path[0], path[1:]
    if isinstance(tree, dict):
        out = dict(tree)
        out[k] = set_path(tree[k], rest, value)
        return out
    if isinstance(tree, (tuple, list)):
        out = list(tree)
        out[k] = set_path(tree[k], rest, value)
        return type(tree)(out)
    raise TypeError(f"cannot set path {path} in {type(tree)}")


def tree_isfinite(a):
    leaves = jax.tree.map(lambda x: jnp.all(jnp.isfinite(x)), a)
    return jax.tree.reduce(jnp.logical_and, leaves, jnp.bool_(True))
