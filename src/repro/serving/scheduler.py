"""Request model + slot scheduler for the continuous-batching engine.

Request lifecycle::

    QUEUED --admit--> ACTIVE --finish--> DONE
    QUEUED --reject (invalid / exceeds cache capacity)--> FAILED
    ACTIVE --preempt (page pressure)--> QUEUED (front; out cleared)

Admission is strict FIFO: the head of the queue is admitted as soon as a
batch slot is free *and* the allocator covers its *prompt* pages
(``blocks_for(prompt_len)`` — no worst-case ``max_new`` reservation; decode
growth allocates pages on demand and preempts a victim under pressure).
No head-of-line bypass keeps the schedule deterministic, which is what
lets the batched engine be compared token-for-token against the
slot-serial reference.

Preemption re-queues the victim at the *front* of the queue.  Every queued
request was submitted after every active one (actives were admitted from
the queue head), and victims are chosen youngest-first, so front re-queue
restores the global FIFO order exactly.  The victim's generated tokens are
discarded and recomputed from scratch on re-admission — greedy decoding
and the seeded sampler are both pure functions of (request, token index),
so the re-run reproduces the identical stream.

Sampling parameters ride on the request: ``temperature`` / ``top_k`` /
``top_p`` / ``seed`` (see ``serving/sampling.py`` for the determinism
contract).

The scheduler is pure bookkeeping (queue + slot binding + states); the
engine owns all compute and cache state.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

QUEUED, ACTIVE, DONE, FAILED = "queued", "active", "done", "failed"


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    top_k: int = 0                 # 0 = no top-k filter
    top_p: float = 1.0             # 1.0 = no nucleus filter
    seed: Optional[int] = None     # None = legacy engine-shared RNG
    uncertainty: bool = False      # request per-token Laplace variance
                                   # (engine must carry a curvature bundle)
    out: List[int] = field(default_factory=list)
    var: List[float] = field(default_factory=list)  # per-token predictive
                                   # variance, parallel to ``out``
    done: bool = False
    error: Optional[str] = None
    state: str = QUEUED
    preemptions: int = 0           # times evicted + re-queued mid-decode


class Scheduler:
    """FIFO queue + slot table.  ``admissible``/``bind``/``release`` are the
    only mutations; the engine polls ``next_queued`` each step."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots

    def submit(self, req: Request) -> None:
        req.state = QUEUED
        self.queue.append(req)

    def reject(self, req: Request, reason: str) -> None:
        req.state = FAILED
        req.error = reason
        req.done = False

    def next_queued(self) -> Optional[Request]:
        return self.queue[0] if self.queue else None

    def free_slot(self) -> Optional[int]:
        for s, r in enumerate(self.slots):
            if r is None:
                return s
        return None

    def bind(self, slot: int, req: Request) -> None:
        assert self.slots[slot] is None and req is self.queue[0]
        self.queue.popleft()
        req.state = ACTIVE
        self.slots[slot] = req

    def release(self, slot: int, *, done: bool = True) -> Request:
        req = self.slots[slot]
        assert req is not None
        self.slots[slot] = None
        req.state = DONE if done else QUEUED
        req.done = done
        return req

    def preempt(self, slot: int) -> Request:
        """Evict the request in ``slot`` back to the *front* of the queue
        (FIFO-preserving: every queued request is younger than any active
        one).  Its emitted tokens are discarded — the re-run recomputes the
        identical stream from scratch."""
        req = self.release(slot, done=False)
        req.out.clear()
        req.var.clear()
        req.preemptions += 1
        self.queue.appendleft(req)
        return req

    @property
    def active(self) -> List[int]:
        return [s for s, r in enumerate(self.slots) if r is not None]

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def queued(self) -> List[Request]:
        return list(self.queue)
