"""Request model + slot scheduler for the continuous-batching engine.

Request lifecycle::

    QUEUED --admit--> ACTIVE --finish--> DONE
    QUEUED --reject (invalid / exceeds cache capacity)--> FAILED

Admission is strict FIFO: the head of the queue is admitted as soon as a
batch slot is free *and* the allocator can cover its worst-case page
reservation (``min(prompt_len + max_new - 1, max_len)`` positions — the
last sampled token is returned but never written, hence the ``- 1``).  No
head-of-line bypass keeps the schedule deterministic, which is what lets
the batched engine be compared token-for-token against the slot-serial
reference.

The scheduler is pure bookkeeping (queue + slot binding + states); the
engine owns all compute and cache state.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

QUEUED, ACTIVE, DONE, FAILED = "queued", "active", "done", "failed"


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    out: List[int] = field(default_factory=list)
    done: bool = False
    error: Optional[str] = None
    state: str = QUEUED


class Scheduler:
    """FIFO queue + slot table.  ``admissible``/``bind``/``release`` are the
    only mutations; the engine polls ``next_queued`` each step."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots

    def submit(self, req: Request) -> None:
        req.state = QUEUED
        self.queue.append(req)

    def reject(self, req: Request, reason: str) -> None:
        req.state = FAILED
        req.error = reason
        req.done = False

    def next_queued(self) -> Optional[Request]:
        return self.queue[0] if self.queue else None

    def free_slot(self) -> Optional[int]:
        for s, r in enumerate(self.slots):
            if r is None:
                return s
        return None

    def bind(self, slot: int, req: Request) -> None:
        assert self.slots[slot] is None and req is self.queue[0]
        self.queue.popleft()
        req.state = ACTIVE
        self.slots[slot] = req

    def release(self, slot: int, *, done: bool = True) -> Request:
        req = self.slots[slot]
        assert req is not None
        self.slots[slot] = None
        req.state = DONE if done else QUEUED
        req.done = done
        return req

    @property
    def active(self) -> List[int]:
        return [s for s, r in enumerate(self.slots) if r is not None]

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def queued(self) -> List[Request]:
        return list(self.queue)
