"""Free-list page allocator for the paged KV cache.

Physical pages are small fixed-size chunks of the cache's sequence axis.
A slot's logical positions ``[0, len)`` map onto an ordered list of pages
through its page table; on completion the pages return to the free list and
are handed to later requests (FIFO, so reuse order is deterministic).

Page 0 is *reserved* as the null page: idle batch rows point their page
table at it, so their (masked, garbage) decode writes can never land inside
a live slot's allocation — the cross-slot cache-corruption class of bug is
structurally impossible rather than merely avoided.

Eviction: under memory pressure the engine preempts a victim request and
reclaims its pages through ``evict`` — same free-list return and the same
double-free / reserved-page guards as ``free`` (a reserved page can never
be evicted), but counted separately (``n_evicted``) so preemption pressure
is observable.  Evicted pages re-enter the FIFO free list, so page reuse
stays deterministic under preemption too.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence

NULL_PAGE = 0


class PageAllocator:
    """FIFO free-list over page ids ``[0, num_pages)`` minus the reserved
    set.  ``alloc`` is atomic (all-or-nothing); ``free`` rejects double
    frees and foreign pages."""

    def __init__(self, num_pages: int, reserved: Sequence[int] = (NULL_PAGE,)):
        if num_pages <= len(set(reserved)):
            raise ValueError(f"num_pages={num_pages} leaves no allocatable "
                             f"pages beyond reserved={sorted(set(reserved))}")
        self.num_pages = num_pages
        self.reserved = frozenset(reserved)
        self._free = deque(p for p in range(num_pages)
                           if p not in self.reserved)
        self._held: set = set()
        self.n_evicted = 0

    @property
    def capacity(self) -> int:
        """Total allocatable pages (reserved pages excluded)."""
        return self.num_pages - len(self.reserved)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def free_pages(self) -> List[int]:
        """Snapshot of the free list (reuse order) — for tests/telemetry."""
        return list(self._free)

    @property
    def held_pages(self) -> List[int]:
        return sorted(self._held)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages off the free list, or ``None`` (and no state
        change) if fewer than ``n`` are free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._held.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        """Return pages to the free list.  Raises on a double free, a
        reserved page, or a page that was never allocated."""
        for p in pages:
            if p in self.reserved:
                raise ValueError(f"page {p} is reserved")
            if p not in self._held:
                raise ValueError(f"page {p} is not held (double free?)")
        for p in pages:
            self._held.discard(p)
            self._free.append(p)

    def evict(self, pages: Sequence[int]) -> None:
        """Reclaim a preempted request's pages.  Identical guards and
        free-list return as ``free`` (a reserved page can never be
        evicted), counted in ``n_evicted``."""
        self.free(pages)
        self.n_evicted += len(pages)
