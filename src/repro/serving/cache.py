"""Paged KV cache: block pools per layer group + gather/scatter views.

Layout
------
For every attention pattern position ``posX`` of the model there is one
``k`` and one ``v`` pool of shape ``(ng, num_pages, page_size, hkv, hd)``
(``ng`` = the model's scan-group leading dim; same dtype as the serve-side
dense cache, bfloat16).  All layers share one *page-id space*: a slot's
page table row lists the physical pages backing its logical positions in
order, and that same row indexes every layer's pools — exactly the
vLLM-style block table, minus per-layer tables.

The default decode route is *block-indexed*: ``model.decode_step`` takes
the pools plus the ``(B, max_blocks)`` page table straight through to
``ops.flash_decode_paged`` — each layer scatters its one new KV row into
the slot's physical page and attends the pool in place (page table as a
scalar-prefetch operand of the Pallas kernel), so no dense per-row view is
ever materialized on the hot path.  ``gather`` + ``scatter_token`` remain
as the *oracle route* (``Engine(decode_route="gather")``): pages gathered
back into the ``(ng, B, S_view, hkv, hd)`` dense cache (``S_view =
max_blocks * page_size``, fixed so the step compiles once), decode against
it, one-token scatter back — the einsum/XLA reference the paged route is
differentially tested against.  Rows whose slot is idle carry a page table
of null pages (page 0, reserved by the allocator), so their writes never
touch a live allocation on either route.

Attention never reads stale bytes from a *reused* page: row ``b`` of the
gathered view is masked to ``[0, len_b)`` by the per-slot length vector
(``ops.flash_decode``), and every position in that prefix was written by
the current owner (prefill covers ``[0, prompt_len)``, decode extends one
position per step) — a recycled page is therefore fully overwritten before
any of it is attended.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp


def _check_supported(model) -> None:
    cfg = model.cfg
    bad = [s.attn for s in model.pattern if s.attn not in ("global", "local")]
    if bad or cfg.encoder_layers or any(s.cross for s in model.pattern):
        raise NotImplementedError(
            f"paged serving engine supports attention-only decoders; "
            f"{cfg.name} has attn kinds "
            f"{sorted({s.attn for s in model.pattern})}"
            + (", encoder/cross-attention" if cfg.encoder_layers else ""))


class PagedKVCache:
    """Owns the pool layout + the pure gather/scatter functions used inside
    the engine's jitted step.  The pools themselves are a plain pytree held
    by the engine (functional updates)."""

    def __init__(self, model, *, batch_slots: int, max_len: int,
                 page_size: int = 8, num_pages: int = None,
                 dtype=jnp.bfloat16):
        _check_supported(model)
        if page_size < 1:
            raise ValueError(f"page_size={page_size}")
        self.model = model
        self.b = batch_slots
        self.max_len = max_len
        self.page_size = page_size
        self.max_blocks = max(1, math.ceil(max_len / page_size))
        self.s_view = self.max_blocks * page_size
        # default capacity: every slot can reach max_len, + 1 null page
        self.num_pages = (1 + batch_slots * self.max_blocks
                          if num_pages is None else num_pages)
        self.dtype = dtype
        cfg = model.cfg
        self.layer_names = [f"pos{i}" for i in range(len(model.pattern))]
        self._kv_shape = (model.n_groups, self.num_pages, page_size,
                          cfg.n_kv_heads, cfg.hd)

    def blocks_for(self, n_positions: int) -> int:
        """Pages needed to back ``n_positions`` logical cache entries."""
        return max(1, math.ceil(n_positions / self.page_size))

    # -- pool construction -------------------------------------------------
    def init_pools(self) -> Dict[str, Dict[str, jax.Array]]:
        """Zeroed pools (structurally — a fresh slot attends nothing but
        positions it wrote, and the null page is all-zero garbage)."""
        return {name: {"k": jnp.zeros(self._kv_shape, self.dtype),
                       "v": jnp.zeros(self._kv_shape, self.dtype)}
                for name in self.layer_names}

    # -- pure views (jit-safe) ---------------------------------------------
    def gather(self, pools, page_table):
        """pools + ``(B, max_blocks)`` page table -> dense decode cache
        ``{posX: {k,v: (ng, B, S_view, hkv, hd)}}`` in logical order."""
        ng = self.model.n_groups

        def one(pool):
            g = jnp.take(pool, page_table, axis=1)  # (ng,B,nb,P,hkv,hd)
            return g.reshape(ng, self.b, self.s_view, *pool.shape[3:])

        return {name: {"k": one(p["k"]), "v": one(p["v"])}
                for name, p in pools.items()}

    def scatter_token(self, pools, dense_cache, page_table, pos):
        """Write each row's KV at logical position ``pos[b]`` (just spliced
        into the dense view by ``decode_step``) back to its physical page."""
        bidx = jnp.arange(self.b)
        page = jnp.take_along_axis(page_table,
                                   (pos // self.page_size)[:, None],
                                   axis=1)[:, 0]
        off = pos % self.page_size
        out = {}
        for name, p in pools.items():
            row_k = dense_cache[name]["k"][:, bidx, pos]    # (ng,B,hkv,hd)
            row_v = dense_cache[name]["v"][:, bidx, pos]
            out[name] = {
                "k": p["k"].at[:, page, off].set(row_k.astype(p["k"].dtype)),
                "v": p["v"].at[:, page, off].set(row_v.astype(p["v"].dtype)),
            }
        return out

    # -- host-side prefill write ------------------------------------------
    def write_prefill(self, pools, pages, prefill_cache, prompt_len: int,
                      row: int = 0):
        """Write row ``row`` of a (possibly multi-request) prefill cache
        (``(ng, B, Tp, hkv, hd)`` leaves) into the first
        ``blocks_for(prompt_len)`` of ``pages``.  Batched admission prefills
        several same-length requests in one forward and peels each row into
        its own slot's pages through this."""
        nb = self.blocks_for(prompt_len)
        if nb > len(pages):
            raise ValueError(f"prompt needs {nb} pages, slot holds "
                             f"{len(pages)}")
        pids = jnp.asarray(pages[:nb], jnp.int32)
        pad = nb * self.page_size - prompt_len
        ng = self.model.n_groups
        out = {}
        for name in self.layer_names:
            src = prefill_cache[name]
            new = {}
            for kv in ("k", "v"):
                x = src[kv][:, row:row + 1, :prompt_len]
                x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                x = x.reshape(ng, nb, self.page_size, *x.shape[3:])
                new[kv] = pools[name][kv].at[:, pids].set(
                    x.astype(self.dtype))
            out[name] = new
        return out
