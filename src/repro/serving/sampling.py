"""Sampling layer for the serving engine: greedy / top-k / top-p with
per-request seeds.

Determinism contract
--------------------
A request's token stream is a pure function of ``(its logits, its sampling
params, its seed, the token index within its own stream)``:

* greedy (``temperature <= 0``) is exactly ``int(np.argmax(row))`` — the
  PR-7 code path, bitwise-unchanged;
* seeded sampling draws token ``i`` with ``fold_in(PRNGKey(seed), i)``, so
  the stream does not depend on batch composition, admission order, or how
  many times the engine's shared RNG was split for *other* requests — and a
  preempted request that recomputes from scratch replays the identical
  stream (token ``i`` is always drawn with the same key);
* top-k keeps the ``k`` highest logits (ties broken by lowest token id,
  stable); top-p keeps the smallest prefix of the descending-probability
  ordering whose mass reaches ``p`` (always at least one token).

Filtering runs in float64 numpy on the host (one row per sampled token —
decode is model-bound, not sampler-bound), the draw through
``jax.random.categorical`` so the same seed gives the same token on every
backend that reproduces the logits.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = float("-inf")


def filter_logits(row: np.ndarray, *, top_k: int = 0,
                  top_p: float = 1.0) -> np.ndarray:
    """Mask ``row`` down to the top-k / nucleus-p support (float64 copy;
    masked entries are ``-inf``).  ``top_k=0`` / ``top_p>=1`` disable the
    respective filter.  At least one token always survives."""
    row = np.asarray(row, np.float64).copy()
    if top_k and top_k < row.size:
        # stable order: descending value, ascending token id on ties
        order = np.lexsort((np.arange(row.size), -row))
        row[order[top_k:]] = NEG_INF
    if 0.0 < top_p < 1.0:
        order = np.lexsort((np.arange(row.size), -row))
        sorted_row = row[order]
        probs = np.exp(sorted_row - sorted_row.max())
        probs /= probs.sum()
        keep = np.cumsum(probs) - probs < top_p   # first token always kept
        row[order[~keep]] = NEG_INF
    return row


def sample_token(row, *, temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 1.0, seed: Optional[int] = None,
                 index: int = 0) -> int:
    """One token from one logits row.  Greedy when ``temperature <= 0``
    (bitwise the PR-7 argmax); otherwise a seeded temperature/top-k/top-p
    draw keyed on ``(seed, index)`` only."""
    row = np.asarray(row)
    if temperature <= 0:
        return int(np.argmax(row))
    filtered = filter_logits(row.astype(np.float64) / float(temperature),
                             top_k=top_k, top_p=top_p)
    key = jax.random.fold_in(jax.random.PRNGKey(0 if seed is None else seed),
                             index)
    return int(jax.random.categorical(key, jnp.asarray(filtered, jnp.float32)))
