"""Continuous-batching inference engine over a paged KV cache.

Per step, every *active* slot decodes one token at its **own** position
(``decode_step`` takes the ``(B,)`` position vector straight through to
the decode kernel's per-row length masking); finished slots free their
pages and the queue refills them in-flight, without touching any other
slot's cache.

Decode routes (``decode_route``):

* ``"paged"`` (default) — block-indexed paged attention: the page table
  rides into ``model.decode_step`` and each attention layer scatters its
  one new KV row into the slot's physical page and attends the pool in
  place (``ops.flash_decode_paged``, page table as a scalar-prefetch
  operand).  No dense ``(B, S_view)`` gather view exists on the hot path.
* ``"gather"`` — the einsum/XLA *oracle*: gather pages into the dense
  view, decode against it, scatter the one new row back.  Retained for
  differential testing (``tests/test_serving.py`` pins paged == gather),
  not as a serving configuration.

Admission & memory pressure: a request is admitted with only its *prompt*
pages (``blocks_for(prompt_len)``) — no worst-case ``max_new``
reservation.  Decode growth allocates one page on demand whenever a slot's
next position crosses a page boundary; if the pool is exhausted the engine
preempts the **youngest** active request (possibly the requester itself),
evicts its pages back to the free list and re-queues it at the queue
front.  Victims recompute from scratch on re-admission — greedy decoding
and the seeded sampler (``serving/sampling.py``) are pure functions of
(request, token index), so the re-run reproduces the identical token
stream.  ``submit`` still rejects requests whose worst-case footprint
exceeds *total* capacity, which is what guarantees the oldest active
request can always make progress (no preemption livelock).

Prefill is batched: all requests admitted in one step are grouped by
prompt length and prefilled in a single forward per group (batch padded to
a power-of-two bucket with duplicate rows so the jit cache stays small);
each row is then written into its own slot's pages.  Grouping by *exact*
length keeps every row's computation identical to its batch-1 prefill, so
batched-vs-serial token parity is preserved.

Termination: a cache of ``max_len`` yields exactly ``max_len`` usable
positions — a prompt of ``Tp`` tokens can emit up to ``max_len - Tp + 1``
tokens (the first comes from the prefill logits; the last sampled token is
returned but never written back).  ``run`` reports — never silently drops —
requests still in flight or queued when ``max_steps`` is hit.

The slot-serial reference engine (``serial_engine`` / ``batch_slots=1``)
runs the identical compute path one request at a time; under greedy
decoding the batched engine must match it token-for-token — including
under eviction pressure (tiny page pools forcing mid-decode preemption).

Uncertainty-aware decoding: constructed with a ``laplace`` head
(:class:`repro.curvature.uncertainty.LaplaceHead`, built from a training
curvature bundle), the engine serves ``Request(uncertainty=True)`` with a
per-token Laplace predictive variance (``req.var``, parallel to
``req.out``) — computed batched inside the decode jit from the hidden
state the normal step already produces.  The uncertainty step functions
are compiled *separately* and only invoked when an uncertainty request is
actually in the batch, so ``uncertainty=False`` traffic runs the original
compiled graphs and its outputs stay bitwise-identical to an engine built
without a bundle (pinned by ``tests/test_curvature.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import sampling
from repro.serving.allocator import PageAllocator
from repro.serving.cache import PagedKVCache
from repro.serving.scheduler import Request, Scheduler

DECODE_ROUTES = ("paged", "gather")


@dataclass
class RunReport:
    """What ``Engine.run`` actually did.  ``unfinished`` (in-flight) and
    ``unserved`` (never admitted) are non-empty only when ``max_steps``
    cut the run short — they are reported, not dropped.  ``preemptions``
    and ``evictions`` are per-run deltas of the engine's obs counters
    (``serve/preemptions`` / ``serve/evicted_pages``) — the registry is
    the source of truth, not hand-carried per-request tallies."""
    steps: int = 0
    completed: List[Request] = field(default_factory=list)
    unfinished: List[Request] = field(default_factory=list)
    unserved: List[Request] = field(default_factory=list)
    failed: List[Request] = field(default_factory=list)
    preemptions: int = 0
    evictions: int = 0                # pages evicted under pressure
    # per-run latency split (ms) from the shared TTFT / decode-gap
    # definitions (repro.obs.latency); None when obs is disabled or the
    # distribution is empty
    ttft_p50_ms: float = None
    ttft_p99_ms: float = None
    decode_p50_ms: float = None
    decode_p99_ms: float = None
    # mean per-token Laplace predictive variance across all served
    # uncertainty=True tokens; None when no uncertainty was requested
    mean_token_variance: float = None

    @property
    def truncated(self) -> bool:
        return bool(self.unfinished or self.unserved)


class Engine:
    """Continuous-batching engine: FIFO admission into ``batch_slots``
    in-flight rows, paged KV cache with free-list reuse and
    eviction/preemption under pressure, grouped batched prefill, and
    block-indexed paged-attention decode steps."""

    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 page_size: int = 8, num_pages: int = None,
                 rng_seed: int = 0, decode_route: str = "paged",
                 laplace=None, obs=None):
        from repro import obs as obs_mod
        if decode_route not in DECODE_ROUTES:
            raise ValueError(f"decode_route={decode_route!r} not in "
                             f"{DECODE_ROUTES}")
        # telemetry (repro.obs): counters are always live (plain host ints
        # — they feed RunReport's aggregates); per-step gauges, the TTFT /
        # decode-gap tracker and JSONL events exist only when enabled, so
        # the disabled engine runs the identical compiled step functions
        # with no extra per-token work (pinned by tests/test_obs.py)
        self.obs = obs_mod.from_config(obs)
        self._c_steps = self.obs.counter("serve/steps")
        self._c_completed = self.obs.counter("serve/completed")
        self._c_rejected = self.obs.counter("serve/rejected")
        self._c_preempt = self.obs.counter("serve/preemptions")
        self._c_evicted = self.obs.counter("serve/evicted_pages")
        self._c_sample = {m: self.obs.counter("serve/sampled",
                                              {"mode": m})
                          for m in ("greedy", "seeded", "shared_rng")}
        self.lat = (obs_mod.RequestLatencyTracker(self.obs.registry)
                    if self.obs.enabled else None)
        self.model = model
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.decode_route = decode_route
        self.kv = PagedKVCache(model, batch_slots=batch_slots,
                               max_len=max_len, page_size=page_size,
                               num_pages=num_pages)
        self.alloc = PageAllocator(self.kv.num_pages)
        self.sched = Scheduler(batch_slots)
        self.pools = self.kv.init_pools()
        self.pos = np.zeros(batch_slots, np.int32)       # per-slot next pos
        self.page_table = np.zeros((batch_slots, self.kv.max_blocks),
                                   np.int32)
        self.last_tok = np.zeros((batch_slots, 1), np.int32)
        self.slot_pages: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_seq = np.zeros(batch_slots, np.int64)  # admission order
        self._seq = 0
        self.n_preemptions = 0
        self.rng = jax.random.PRNGKey(rng_seed)
        self._failed: List[Request] = []
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(self._decode_paged if decode_route == "paged"
                             else self._decode_gather)
        # Laplace uncertainty head (repro.curvature): separate jits so the
        # plain path's compiled graphs — and outputs — are untouched
        self.laplace = laplace
        if laplace is not None:
            self._prefill_unc = jax.jit(self._prefill_with_var)
            self._step_unc = jax.jit(
                self._decode_paged_unc if decode_route == "paged"
                else self._decode_gather_unc)

    # ------------------------------------------------------------------
    @property
    def cache(self):
        """The paged KV pools (zero at construction, structurally)."""
        return self.pools

    def reset(self) -> None:
        """Clear all serving state; keeps the compiled step functions."""
        self.pools = self.kv.init_pools()
        self.alloc = PageAllocator(self.kv.num_pages)
        self.sched = Scheduler(self.b)
        self.pos[:] = 0
        self.page_table[:] = 0
        self.last_tok[:] = 0
        self.slot_pages = [[] for _ in range(self.b)]
        self.slot_seq[:] = 0
        self._seq = 0
        self.n_preemptions = 0
        self._failed = []
        if self.lat is not None:
            self.lat.reset()

    # ------------------------------------------------------------------
    def _decode_paged(self, params, pools, page_table, pos, toks):
        """Block-indexed route: pools + page table straight into the model;
        the new KV row is scattered inside each attention layer."""
        logits, pools = self.model.decode_step(params, pools, toks, pos,
                                               page_table=page_table)
        return logits[:, -1], pools

    def _decode_gather(self, params, pools, page_table, pos, toks):
        """Oracle route: dense gather view -> decode -> one-token scatter."""
        dense = self.kv.gather(pools, page_table)
        logits, new_dense = self.model.decode_step(params, dense, toks, pos)
        pools = self.kv.scatter_token(pools, new_dense, page_table, pos)
        return logits[:, -1], pools

    # -- uncertainty variants: the same step + the Laplace variance head --
    def _prefill_with_var(self, params, batch):
        logits, cache, h = self.model.prefill(params, batch,
                                              return_hidden=True)
        return logits, cache, self.laplace.variance(h)

    def _decode_paged_unc(self, params, pools, page_table, pos, toks):
        logits, pools, h = self.model.decode_step(
            params, pools, toks, pos, page_table=page_table,
            return_hidden=True)
        return logits[:, -1], pools, self.laplace.variance(h)

    def _decode_gather_unc(self, params, pools, page_table, pos, toks):
        dense = self.kv.gather(pools, page_table)
        logits, new_dense, h = self.model.decode_step(
            params, dense, toks, pos, return_hidden=True)
        pools = self.kv.scatter_token(pools, new_dense, page_table, pos)
        return logits[:, -1], pools, self.laplace.variance(h)

    def _sample(self, req: Request, logits_row) -> int:
        """One token for ``req``.  Greedy is the PR-7 argmax, bitwise; a
        seeded request draws token ``len(req.out)`` of its own stream
        (batch-composition independent, replay-identical after preemption);
        an unseeded stochastic request keeps the legacy engine-shared RNG."""
        if req.temperature <= 0:
            self._c_sample["greedy"].inc()
            return int(np.argmax(logits_row))
        if req.seed is None:
            self._c_sample["shared_rng"].inc()
            self.rng, k = jax.random.split(self.rng)
            return int(jax.random.categorical(
                k, jnp.asarray(logits_row) / req.temperature))
        self._c_sample["seeded"].inc()
        return sampling.sample_token(
            logits_row, temperature=req.temperature, top_k=req.top_k,
            top_p=req.top_p, seed=req.seed, index=len(req.out))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; invalid ones are rejected with ``req.error``
        set (returned ``False``) instead of wedging the queue.  The
        capacity check is against the *total* pool (a request must be able
        to run alone) — admission itself reserves only prompt pages."""
        tp = len(req.prompt)
        if req.uncertainty and self.laplace is None:
            self.sched.reject(
                req, "uncertainty requested but engine has no curvature "
                     "bundle (construct with laplace=LaplaceHead(...))")
        elif tp == 0:
            self.sched.reject(req, "empty prompt")
        elif tp > self.max_len:
            self.sched.reject(
                req, f"prompt length {tp} exceeds cache max_len "
                     f"{self.max_len}")
        elif (self.kv.blocks_for(min(tp + req.max_new - 1, self.max_len))
              > self.alloc.capacity):
            self.sched.reject(
                req, "page reservation exceeds total cache capacity")
        else:
            self.sched.submit(req)
            if self.lat is not None:
                self.lat.on_submit(req.uid)
            return True
        self._c_rejected.inc()
        self._failed.append(req)
        return False

    def _finish(self, slot: int) -> None:
        req = self.sched.slots[slot]
        self._c_completed.inc()
        if self.obs.enabled and req is not None:
            ttft = (self.lat.ttft.get(req.uid) if self.lat is not None
                    else None)
            self.obs.emit("serve_request", uid=req.uid,
                          n_tokens=len(req.out),
                          preemptions=req.preemptions,
                          ttft_ms=(None if ttft is None else ttft * 1e3))
        self.sched.release(slot, done=True)
        self.alloc.free(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot] = 0     # back to the null page
        self.pos[slot] = 0
        self.last_tok[slot] = 0

    def _maybe_finish(self, slot: int) -> None:
        req = self.sched.slots[slot]
        # pos == max_len -> no room to write the last sampled token's KV;
        # every position [0, max_len) has been used (no early cutoff)
        if len(req.out) >= req.max_new or self.pos[slot] >= self.max_len:
            self._finish(slot)

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s request: pages back to the free list, request
        to the queue front (FIFO-preserving), emitted tokens discarded —
        the re-run recomputes the identical stream from scratch."""
        self.sched.preempt(slot)
        self._c_preempt.inc()
        self._c_evicted.inc(len(self.slot_pages[slot]))
        self.alloc.evict(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot] = 0
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        self.n_preemptions += 1

    def _grow(self) -> None:
        """Page-on-demand: before the decode step, every active slot must
        own the page backing the position it is about to write.  Oldest
        slots grow first; under exhaustion the youngest active request is
        preempted (possibly the requester itself, which then waits for the
        older ones — the FIFO head can always make progress)."""
        order = sorted(self.sched.active, key=lambda s: self.slot_seq[s])
        for slot in order:
            while (self.sched.slots[slot] is not None
                   and len(self.slot_pages[slot])
                   < self.kv.blocks_for(int(self.pos[slot]) + 1)):
                got = self.alloc.alloc(1)
                if got is not None:
                    self.page_table[slot, len(self.slot_pages[slot])] = got[0]
                    self.slot_pages[slot].append(got[0])
                    continue
                victim = max(self.sched.active,
                             key=lambda s: self.slot_seq[s])
                self._preempt(victim)
                if victim == slot:
                    break             # self-preempted: sit out this step

    def _admit(self) -> List[Tuple[Request, int]]:
        """Fill free slots from the queue (strict FIFO), then prefill all
        admissions of this step in batched groups of equal prompt length.
        Each admission reserves only its prompt pages and emits the first
        token from its prefill logits row."""
        admitted: List[Tuple[Request, int]] = []
        while True:
            req = self.sched.next_queued()
            if req is None:
                break
            slot = self.sched.free_slot()
            if slot is None:
                break
            pages = self.alloc.alloc(self.kv.blocks_for(len(req.prompt)))
            if pages is None:        # wait for active slots to free pages
                break
            self.sched.bind(slot, req)
            self._seq += 1
            self.slot_seq[slot] = self._seq
            self.slot_pages[slot] = pages
            self.page_table[slot] = 0
            self.page_table[slot, :len(pages)] = pages
            admitted.append((req, slot))

        ems: List[Tuple[Request, int]] = []
        by_len = {}
        for req, slot in admitted:
            by_len.setdefault(len(req.prompt), []).append((req, slot))
        for tp in sorted(by_len):
            group = by_len[tp]
            bucket = 1                # pad to a power of two: bounded jit
            while bucket < len(group):   # cache (#lengths x log2 slots)
                bucket *= 2
            toks = [r.prompt for r, _ in group]
            toks += [toks[0]] * (bucket - len(group))   # rows discarded
            feed = {"tokens": jnp.asarray(toks, jnp.int32)}
            want_unc = self.laplace is not None and any(
                r.uncertainty for r, _ in group)
            if want_unc:
                logits, cache, var = self._prefill_unc(self.params, feed)
                var = np.asarray(var)
            else:
                logits, cache = self._prefill(self.params, feed)
            logits = np.asarray(logits)
            for row, (req, slot) in enumerate(group):
                self.pools = self.kv.write_prefill(
                    self.pools, self.slot_pages[slot], cache, tp, row=row)
                self.pos[slot] = tp
                tok = self._sample(req, logits[row, -1])
                req.out.append(tok)
                if want_unc and req.uncertainty:
                    req.var.append(float(var[row, tok]))
                self.last_tok[slot, 0] = tok
                if self.lat is not None:
                    self.lat.on_emit(req.uid)
                ems.append((req, tok))
                self._maybe_finish(slot)
        return ems

    def step_once(self) -> List[Tuple[Request, int]]:
        """Admit what fits, grow pages (evicting under pressure), then run
        one batched decode step.  Returns the ``(request, token)``
        emissions of this call."""
        ems = self._admit()
        self._grow()
        active = self.sched.active
        if not active:
            return self._post_step(ems)
        args = (self.params, self.pools, jnp.asarray(self.page_table),
                jnp.asarray(self.pos), jnp.asarray(self.last_tok))
        want_unc = self.laplace is not None and any(
            self.sched.slots[s].uncertainty for s in active)
        if want_unc:
            logits, self.pools, var = self._step_unc(*args)
            var = np.asarray(var)
        else:
            logits, self.pools = self._step(*args)
        logits = np.asarray(logits)              # (B, vocab) float32
        for s in active:
            self.pos[s] += 1                     # each wrote its last token
        for s in active:
            req = self.sched.slots[s]
            tok = self._sample(req, logits[s])
            req.out.append(tok)
            if want_unc and req.uncertainty:
                req.var.append(float(var[s, tok]))
            self.last_tok[s, 0] = tok
            if self.lat is not None:
                self.lat.on_emit(req.uid)
            ems.append((req, tok))
            self._maybe_finish(s)
        return self._post_step(ems)

    def _post_step(self, ems):
        """Per-step bookkeeping: the step counter always; occupancy gauges
        only when enabled (they are point-in-time, not aggregates)."""
        self._c_steps.inc()
        if self.obs.enabled:
            self.obs.gauge("serve/queue_depth").set(len(self.sched.queue))
            self.obs.gauge("serve/active_slots").set(self.sched.n_active)
            self.obs.gauge("serve/pages_in_use").set(
                self.alloc.capacity - self.alloc.n_free)
        return ems

    @property
    def idle(self) -> bool:
        return self.sched.n_active == 0 and not self.sched.queue

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 1000
            ) -> RunReport:
        """Serve ``requests`` to completion (or ``max_steps``).  The report
        lists completed, in-flight-unfinished, never-admitted and rejected
        requests — nothing is silently dropped."""
        # counter values at run start: the report's aggregates are per-run
        # deltas, so warmup runs on a shared engine don't pollute them
        p0, e0 = self._c_preempt.value, self._c_evicted.value
        if self.lat is not None:
            self.lat.reset()          # per-run latency distributions
        for r in requests:
            self.submit(r)
        steps = 0
        while self.sched.n_active or self.sched.queue:
            if steps >= max_steps:
                break
            self.step_once()
            steps += 1
        token_vars = [v for r in requests for v in r.var]
        lat_pcts = {}
        if self.lat is not None:
            lat_pcts = {k: v
                        for k, v in self.lat.percentiles_or_none().items()
                        if v is not None}
        report = RunReport(
            steps=steps,
            completed=[r for r in requests if r.done],
            unfinished=[self.sched.slots[s] for s in self.sched.active],
            unserved=self.sched.queued,
            failed=list(self._failed),
            preemptions=int(self._c_preempt.value - p0),
            evictions=int(self._c_evicted.value - e0),
            mean_token_variance=(float(np.mean(token_vars))
                                 if token_vars else None),
            **lat_pcts)
        if self.obs.enabled:
            self.obs.emit("serve_run", steps=steps,
                          completed=len(report.completed),
                          preemptions=report.preemptions,
                          evictions=report.evictions, **lat_pcts)
        if report.truncated:
            print(f"[serve] max_steps={max_steps} hit: "
                  f"{len(report.unfinished)} in flight, "
                  f"{len(report.unserved)} still queued "
                  f"(uids {[r.uid for r in report.unfinished + report.unserved]})")
        return report


def serial_engine(model, params, *, max_len: int, page_size: int = 8,
                  rng_seed: int = 0, decode_route: str = "paged",
                  laplace=None, obs=None) -> Engine:
    """The slot-serial reference: one slot, so requests are served strictly
    one at a time through the *identical* compute path.  Under greedy
    decoding the batched engine must match this token-for-token."""
    return Engine(model, params, batch_slots=1, max_len=max_len,
                  page_size=page_size, rng_seed=rng_seed,
                  decode_route=decode_route, laplace=laplace, obs=obs)
