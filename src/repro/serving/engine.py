"""Continuous-batching inference engine over a paged KV cache.

Per step, every *active* slot decodes one token at its **own** position
(``decode_step`` takes the ``(B,)`` position vector straight through to
``ops.flash_decode``'s per-row length masking); finished slots free their
pages and the queue refills them in-flight, without touching any other
slot's cache:

* prefill is a one-shot ``model.prefill`` on just that request (batch 1),
  written only into the slot's freshly allocated pages — it cannot advance
  or overwrite another active slot's entries;
* idle rows ride the batched step against the reserved null page, so their
  masked garbage writes also can't land in a live allocation;
* a slot only ever attends ``[0, its_len)`` — the per-slot length vector is
  the mask, so zeroed/stale cache beyond a slot's length never pollutes its
  softmax.

Termination: a cache of ``max_len`` yields exactly ``max_len`` usable
positions — a prompt of ``Tp`` tokens can emit up to ``max_len - Tp + 1``
tokens (the first comes from the prefill logits; the last sampled token is
returned but never written back).  ``run`` reports — never silently drops —
requests still in flight or queued when ``max_steps`` is hit.

The slot-serial reference engine (``serial_engine`` / ``batch_slots=1``)
runs the identical compute path one request at a time; under greedy
decoding the batched engine must match it token-for-token.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.allocator import PageAllocator
from repro.serving.cache import PagedKVCache
from repro.serving.scheduler import Request, Scheduler


@dataclass
class RunReport:
    """What ``Engine.run`` actually did.  ``unfinished`` (in-flight) and
    ``unserved`` (never admitted) are non-empty only when ``max_steps``
    cut the run short — they are reported, not dropped."""
    steps: int = 0
    completed: List[Request] = field(default_factory=list)
    unfinished: List[Request] = field(default_factory=list)
    unserved: List[Request] = field(default_factory=list)
    failed: List[Request] = field(default_factory=list)

    @property
    def truncated(self) -> bool:
        return bool(self.unfinished or self.unserved)


class Engine:
    """Continuous-batching engine: FIFO admission into ``batch_slots``
    in-flight rows, paged KV cache with free-list reuse, one-shot prefill
    per admitted request, flash-decode batched steps."""

    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 page_size: int = 8, num_pages: int = None,
                 rng_seed: int = 0):
        self.model = model
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.kv = PagedKVCache(model, batch_slots=batch_slots,
                               max_len=max_len, page_size=page_size,
                               num_pages=num_pages)
        self.alloc = PageAllocator(self.kv.num_pages)
        self.sched = Scheduler(batch_slots)
        self.pools = self.kv.init_pools()
        self.pos = np.zeros(batch_slots, np.int32)       # per-slot next pos
        self.page_table = np.zeros((batch_slots, self.kv.max_blocks),
                                   np.int32)
        self.last_tok = np.zeros((batch_slots, 1), np.int32)
        self.slot_pages: List[List[int]] = [[] for _ in range(batch_slots)]
        self.rng = jax.random.PRNGKey(rng_seed)
        self._failed: List[Request] = []
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(self._decode_fn)

    # ------------------------------------------------------------------
    @property
    def cache(self):
        """The paged KV pools (zero at construction, structurally)."""
        return self.pools

    def reset(self) -> None:
        """Clear all serving state; keeps the compiled step functions."""
        self.pools = self.kv.init_pools()
        self.alloc = PageAllocator(self.kv.num_pages)
        self.sched = Scheduler(self.b)
        self.pos[:] = 0
        self.page_table[:] = 0
        self.last_tok[:] = 0
        self.slot_pages = [[] for _ in range(self.b)]
        self._failed = []

    # ------------------------------------------------------------------
    def _decode_fn(self, params, pools, page_table, pos, toks):
        dense = self.kv.gather(pools, page_table)
        logits, new_dense = self.model.decode_step(params, dense, toks, pos)
        pools = self.kv.scatter_token(pools, new_dense, page_table, pos)
        return logits[:, -1], pools

    def _sample(self, logits_row, temperature: float) -> int:
        if temperature <= 0:
            return int(np.argmax(logits_row))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(
            k, jnp.asarray(logits_row) / temperature))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; invalid ones are rejected with ``req.error``
        set (returned ``False``) instead of wedging the queue."""
        tp = len(req.prompt)
        if tp == 0:
            self.sched.reject(req, "empty prompt")
        elif tp > self.max_len:
            self.sched.reject(
                req, f"prompt length {tp} exceeds cache max_len "
                     f"{self.max_len}")
        elif (self.kv.blocks_for(min(tp + req.max_new - 1, self.max_len))
              > self.alloc.capacity):
            self.sched.reject(
                req, "page reservation exceeds total cache capacity")
        else:
            self.sched.submit(req)
            return True
        self._failed.append(req)
        return False

    def _finish(self, slot: int) -> None:
        self.sched.release(slot, done=True)
        self.alloc.free(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot] = 0     # back to the null page
        self.pos[slot] = 0
        self.last_tok[slot] = 0

    def _maybe_finish(self, slot: int) -> None:
        req = self.sched.slots[slot]
        # pos == max_len -> no room to write the last sampled token's KV;
        # every position [0, max_len) has been used (no early cutoff)
        if len(req.out) >= req.max_new or self.pos[slot] >= self.max_len:
            self._finish(slot)

    def _admit(self) -> List[Tuple[Request, int]]:
        """Fill free slots from the queue (strict FIFO).  Each admission
        prefills batch-1 into the slot's own pages and emits the first
        token from the prefill logits."""
        ems: List[Tuple[Request, int]] = []
        while True:
            req = self.sched.next_queued()
            if req is None:
                break
            slot = self.sched.free_slot()
            if slot is None:
                break
            tp = len(req.prompt)
            need = self.kv.blocks_for(min(tp + req.max_new - 1,
                                          self.max_len))
            pages = self.alloc.alloc(need)
            if pages is None:        # wait for active slots to free pages
                break
            self.sched.bind(slot, req)
            self.slot_pages[slot] = pages
            self.page_table[slot] = 0
            self.page_table[slot, :len(pages)] = pages
            logits, cache = self._prefill(
                self.params, {"tokens": jnp.asarray([req.prompt], jnp.int32)})
            self.pools = self.kv.write_prefill(self.pools, pages, cache, tp)
            self.pos[slot] = tp
            tok = self._sample(np.asarray(logits)[0, -1], req.temperature)
            req.out.append(tok)
            self.last_tok[slot, 0] = tok
            ems.append((req, tok))
            self._maybe_finish(slot)
        return ems

    def step_once(self) -> List[Tuple[Request, int]]:
        """Admit what fits, then run one batched decode step.  Returns the
        ``(request, token)`` emissions of this call."""
        ems = self._admit()
        active = self.sched.active
        if not active:
            return ems
        logits, self.pools = self._step(
            self.params, self.pools, jnp.asarray(self.page_table),
            jnp.asarray(self.pos), jnp.asarray(self.last_tok))
        logits = np.asarray(logits)              # (B, vocab) float32
        for s in active:
            self.pos[s] += 1                     # each wrote its last token
        for s in active:
            req = self.sched.slots[s]
            tok = self._sample(logits[s], req.temperature)
            req.out.append(tok)
            self.last_tok[s, 0] = tok
            ems.append((req, tok))
            self._maybe_finish(s)
        return ems

    @property
    def idle(self) -> bool:
        return self.sched.n_active == 0 and not self.sched.queue

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 1000
            ) -> RunReport:
        """Serve ``requests`` to completion (or ``max_steps``).  The report
        lists completed, in-flight-unfinished, never-admitted and rejected
        requests — nothing is silently dropped."""
        for r in requests:
            self.submit(r)
        steps = 0
        while self.sched.n_active or self.sched.queue:
            if steps >= max_steps:
                break
            self.step_once()
            steps += 1
        report = RunReport(
            steps=steps,
            completed=[r for r in requests if r.done],
            unfinished=[self.sched.slots[s] for s in self.sched.active],
            unserved=self.sched.queued,
            failed=list(self._failed))
        if report.truncated:
            print(f"[serve] max_steps={max_steps} hit: "
                  f"{len(report.unfinished)} in flight, "
                  f"{len(report.unserved)} still queued "
                  f"(uids {[r.uid for r in report.unfinished + report.unserved]})")
        return report


def serial_engine(model, params, *, max_len: int, page_size: int = 8,
                  rng_seed: int = 0) -> Engine:
    """The slot-serial reference: one slot, so requests are served strictly
    one at a time through the *identical* compute path.  Under greedy
    decoding the batched engine must match this token-for-token."""
    return Engine(model, params, batch_slots=1, max_len=max_len,
                  page_size=page_size, rng_seed=rng_seed)
