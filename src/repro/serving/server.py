"""Back-compat façade for the serving package.

The fixed-slot loop that used to live here had three real bugs — prefill
rewrote *other* slots' cache entries, ``run`` stomped every slot's position
with the batch max, and termination ended one token early — all rooted in
the same missing primitive: per-slot positions.  The rebuilt engine
(``repro.serving.engine``) fixes them structurally: continuous batching
with per-slot admission, a paged KV cache with free-list reuse
(``allocator``/``cache``), one-shot per-request prefill, and flash-decode
steps masked by a per-slot length vector.

The engine has since grown block-indexed paged-attention decode (the page
table rides into the kernel; ``decode_route="gather"`` keeps the dense
gather view as the differential oracle), eviction/preemption under page
pressure, batched grouped prefill, per-request sampling
(``sampling``: greedy / top-k / top-p with per-request seeds), and
uncertainty-aware decoding: built with ``laplace=LaplaceHead(bundle)``
(``repro.curvature``) the engine serves ``Request(uncertainty=True)``
with per-token Laplace predictive variance (see ``docs/influence.md``).

Import from here for the stable entry points; the submodules hold the
pieces:

* :class:`Engine` / :func:`serial_engine` / :class:`RunReport` — engine
* :class:`Request` — request dataclass (queue states in ``scheduler``;
  sampling params ``temperature``/``top_k``/``top_p``/``seed`` ride on it)
* :class:`PageAllocator` / :class:`PagedKVCache` — cache machinery
* :func:`sample_token` / :func:`filter_logits` — the sampling layer
"""
from repro.serving.allocator import NULL_PAGE, PageAllocator
from repro.serving.cache import PagedKVCache
from repro.serving.engine import DECODE_ROUTES, Engine, RunReport, serial_engine
from repro.serving.sampling import filter_logits, sample_token
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "RunReport", "Request", "Scheduler", "PageAllocator",
           "PagedKVCache", "serial_engine", "NULL_PAGE", "DECODE_ROUTES",
           "sample_token", "filter_logits"]
