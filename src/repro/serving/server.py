"""Batched serving: fixed-slot continuous batching over prefill/decode.

Requests (token prompts) fill batch slots; each engine step decodes one
token for every active slot; finished slots are refilled from the queue
(prefill for a single slot re-runs the prompt against that slot's cache
region).  This is the serve-side counterpart of the decode_32k /
long_500k dry-run shapes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as PM


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    out: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 rng_seed: int = 0):
        self.model = model
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        cache_defs = model.cache_defs(batch_slots, max_len)
        # the KV cache must start ZEROED: a fresh (or refilled) slot
        # attends positions it never wrote, and any non-zero init there
        # leaks into its logits.  This used to go through the *weight*
        # initializer (PM.materialize with a hardcoded PRNGKey(0)) and was
        # only correct because every cache ParamDef happens to carry
        # init="zeros" — a convention one new cache leaf could silently
        # break.  Build the zeros structurally instead; no RNG involved.
        self.cache = jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype), cache_defs,
            is_leaf=PM.is_def)
        self.pos = np.zeros(batch_slots, np.int32)      # per-slot next pos
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.rng = jax.random.PRNGKey(rng_seed)
        self._decode = jax.jit(model.decode_step)
        self._last_tok = np.zeros((batch_slots, 1), np.int32)

    # ------------------------------------------------------------------
    def _prefill_slot(self, slot: int, req: Request):
        """Feed the prompt token-by-token through decode_step for this slot.

        (A production engine prefills in one shot per slot; slot-wise decode
        keeps the cache layout identical and is plenty for tests/examples.)"""
        for i, t in enumerate(req.prompt):
            toks = self._last_tok.copy()
            toks[slot, 0] = t
            # decode advances every slot's cache at its own position — we run
            # the engine step only when all slots are aligned, so here we use
            # a masked single-slot step: position = this slot's pos.
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.int32(self.pos[slot]))
            self.pos[slot] += 1
        self._last_tok[slot, 0] = req.prompt[-1]

    def _sample(self, logits, temperature):
        if temperature <= 0:
            return int(jnp.argmax(logits))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, logits / temperature))

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 1000):
        queue = list(requests)
        active = 0
        # fill slots
        for s in range(self.b):
            if queue:
                req = queue.pop(0)
                self.slot_req[s] = req
                self._prefill_slot(s, req)
                active += 1

        step = 0
        while (active or queue) and step < max_steps:
            step += 1
            pos = int(self.pos.max())
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self._last_tok),
                jnp.int32(pos))
            self.pos[:] = pos + 1
            logits = np.asarray(logits[:, -1])
            for s, req in enumerate(self.slot_req):
                if req is None or req.done:
                    continue
                tok = self._sample(jnp.asarray(logits[s]), req.temperature)
                req.out.append(tok)
                self._last_tok[s, 0] = tok
                if len(req.out) >= req.max_new or pos + 1 >= self.max_len - 1:
                    req.done = True
                    active -= 1
                    self.slot_req[s] = None
                    if queue:   # refill the slot
                        nreq = queue.pop(0)
                        self.slot_req[s] = nreq
                        self._prefill_slot(s, nreq)
                        active += 1
        return requests
