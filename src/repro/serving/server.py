"""Back-compat façade for the serving package.

The fixed-slot loop that used to live here had three real bugs — prefill
rewrote *other* slots' cache entries, ``run`` stomped every slot's position
with the batch max, and termination ended one token early — all rooted in
the same missing primitive: per-slot positions.  The rebuilt engine
(``repro.serving.engine``) fixes them structurally: continuous batching
with per-slot admission, a paged KV cache with free-list reuse
(``allocator``/``cache``), one-shot per-request prefill, and flash-decode
steps masked by a per-slot length vector.

Import from here for the stable entry points; the submodules hold the
pieces:

* :class:`Engine` / :func:`serial_engine` / :class:`RunReport` — engine
* :class:`Request` — request dataclass (queue states in ``scheduler``)
* :class:`PageAllocator` / :class:`PagedKVCache` — cache machinery
"""
from repro.serving.allocator import NULL_PAGE, PageAllocator
from repro.serving.cache import PagedKVCache
from repro.serving.engine import Engine, RunReport, serial_engine
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "RunReport", "Request", "Scheduler", "PageAllocator",
           "PagedKVCache", "serial_engine", "NULL_PAGE"]
