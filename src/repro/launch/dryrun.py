import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (lower succeeds),
  * the partitioned program compiles (no unsupported collectives),
  * it fits (memory_analysis), and
  * the roofline terms are derivable (cost_analysis + HLO collective scan).

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multi_pod]
  python -m repro.launch.dryrun --all [--multi_pod]   # every cell, resumable
Results land in benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import optimizers
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import KFACConfig
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, train_batch_specs, rng_spec
from repro.models.lm import LM

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

_COLL_RE = re.compile(
    r"(\w[\w\.\-]*) = \S+?\s*(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)\(")
_SHAPE_RE = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str):
    """Per-device bytes moved over links, by collective type.

    Model: ring algorithms — all-gather/reduce-scatter/all-to-all/permute
    move ~result-size bytes per device; all-reduce ~2x (RS + AG phases).
    """
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        mm = _COLL_RE.search(line)
        if not mm:
            continue
        kind = mm.group(2)
        sm = _SHAPE_RE.search(line)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                size *= int(d)
        factor = 2.0 if kind == "all-reduce" else 1.0
        if kind == "reduce-scatter":
            gm = _GROUP_RE.search(line)
            if gm:  # result is the scattered shard; ring moves ~input bytes
                size *= len(gm.group(1).split(","))
        out[kind] += int(size * factor)
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items() if k != "count")
    return out


def _cost_dict(compiled):
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        out = {}
        for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes"):
            if hasattr(m, k):
                out[k] = int(getattr(m, k))
        return out
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (record, lowered/compiled handles are not kept)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "per-assignment skip (see DESIGN.md)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    kcfg = KFACConfig()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": list(mesh.devices.shape), "kind": shape.kind}
    t0 = time.time()

    if shape.kind == "train":
        lm = LM(cfg, kcfg, mesh, compute_dtype=jnp.bfloat16, fsdp=True)
        opt = optimizers.kfac(lm, kcfg, mesh)
        eng = opt.engine   # the jit-able pipeline stages, lowered one by one
        params_abs = lm.abstract_params(jnp.float32)
        batch_abs = train_batch_specs(cfg, shape, mesh)
        rng_abs = rng_spec(mesh)
        state_abs = jax.eval_shape(opt.init, params_abs, batch_abs)
        state_sh = opt.state_shardings(state_abs, lm.param_shardings(), mesh)
        state_abs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            state_abs, state_sh)

        def train_step(state, params, batch, rng):
            state, grads, metrics = eng.stats_grads(state, params, batch, rng)
            params, state, um = eng.apply_update(state, params, grads, batch,
                                                 rng)
            return params, state

        with mesh:
            lowered = jax.jit(train_step).lower(state_abs, params_abs,
                                                batch_abs, rng_abs)
            compiled = lowered.compile()
        rec["aux"] = {}
        # amortized inverse refresh, lowered separately (every T3 steps)
        with mesh:
            low_inv = jax.jit(eng.refresh_inverses).lower(state_abs)
            comp_inv = low_inv.compile()
        rec["aux"]["refresh_inverses"] = {
            "cost": _cost_dict(comp_inv),
            "hlo": hlo_cost.analyze(comp_inv.as_text()),
        }
        # the distributed refresh service (refresh_mode="sharded"/"overlap"):
        # block-parallel inverses over the flattened mesh, lowered as its
        # own stage so the per-device Σd³/P cost is visible next to the
        # serial spike above
        from repro.distributed.refresh import build_sharded_refresh
        shr = build_sharded_refresh(eng, mesh=mesh)
        gamma_abs = jax.ShapeDtypeStruct((), jnp.float32)
        with mesh:
            comp_shr = shr.lower(state_abs.factors, gamma_abs,
                                 state_abs.inv).compile()
        rec["aux"]["refresh_sharded"] = {
            "plan": {"n_shards": shr.plan.n_shards,
                     "serial_cost": shr.plan.serial_cost(),
                     "parallel_cost": shr.plan.parallel_cost(),
                     "balance_ratio": shr.plan.balance_ratio()},
            "cost": _cost_dict(comp_shr),
            "hlo": hlo_cost.analyze(comp_shr.as_text()),
        }
        # the fused fixed-lr update chain (use_rescale=False): one jit for
        # precondition+momentum+clip+apply vs the three separately-staged
        # ops it replaces — the bytes delta is the fusion's HBM win (each
        # stage boundary writes and re-reads a weight-shaped intermediate)
        from repro.utils import tree as T
        kcfg_f = kcfg.replace(use_rescale=False, fixed_momentum=0.9,
                              clip_delta_norm=1.0)
        eng_f = optimizers.kfac(lm, kcfg_f, mesh).engine
        grads_abs = params_abs

        def fused_chain(state, params, grads, batch, rng):
            p, s, _ = eng_f.apply_update_fused(state, params, grads,
                                               batch, rng)
            return p, s.delta0

        def ref_precond(state, params, grads):
            grads_reg = T.tree_axpy(kcfg_f.eta,
                                    T.tree_cast(params, jnp.float32),
                                    T.tree_cast(grads, jnp.float32))
            return T.tree_scale(
                eng_f._precondition(grads_reg, state.inv, state),
                kcfg_f.fixed_lr)

        def ref_momentum(delta, state):
            return jax.tree.map(
                lambda d, m: d + kcfg_f.fixed_momentum * m,
                delta, state.delta0)

        def ref_clip_apply(vel, params):
            norm = jnp.sqrt(T.tree_sqnorm(vel))
            factor = jnp.minimum(
                jnp.float32(1.0),
                kcfg_f.clip_delta_norm / jnp.maximum(norm, 1e-20))
            return jax.tree.map(
                lambda p, d: p + (factor * d).astype(p.dtype), params, vel)

        with mesh:
            comp_fused = jax.jit(fused_chain).lower(
                state_abs, params_abs, grads_abs, batch_abs,
                rng_abs).compile()
            delta_abs = jax.eval_shape(ref_precond, state_abs, params_abs,
                                       grads_abs)
            ref_comps = {
                "precondition": jax.jit(ref_precond).lower(
                    state_abs, params_abs, grads_abs).compile(),
                "momentum": jax.jit(ref_momentum).lower(
                    delta_abs, state_abs).compile(),
                "clip_apply": jax.jit(ref_clip_apply).lower(
                    delta_abs, params_abs).compile(),
            }
        fused_hlo = hlo_cost.analyze(comp_fused.as_text())
        ref_hlos = {k: hlo_cost.analyze(c.as_text())
                    for k, c in ref_comps.items()}
        ref_bytes = sum(h["bytes"] for h in ref_hlos.values())
        rec["aux"]["update_chain"] = {
            "fused": {"cost": _cost_dict(comp_fused), "hlo": fused_hlo},
            "reference": {"stages": ref_hlos, "hlo_bytes": ref_bytes,
                          "hlo_flops": sum(h["flops"]
                                           for h in ref_hlos.values())},
            "bytes_saved_fraction":
                1.0 - fused_hlo["bytes"] / max(ref_bytes, 1.0),
        }
    else:
        lm = LM(cfg, kcfg, mesh, compute_dtype=jnp.bfloat16, fsdp=False)
        # huge (MoE) models cannot hold bf16 params model-sharded only at
        # serve time; fall back to FSDP storage (EP-style serving)
        if lm.n_params() * 2 > 8e9 * 16:
            lm = LM(cfg, kcfg, mesh, compute_dtype=jnp.bfloat16, fsdp=True)
        rec["serve_fsdp"] = lm.fsdp
        params_abs = lm.abstract_params(jnp.bfloat16)
        spec = input_specs(lm, shape, mesh)
        with mesh:
            if shape.kind == "prefill":
                lowered = jax.jit(lm.prefill).lower(params_abs, spec["batch"])
            else:
                lowered = jax.jit(lm.decode_step).lower(
                    params_abs, spec["cache"], spec["tokens"], spec["pos"])
            compiled = lowered.compile()

    rec["cost"] = {k: v for k, v in _cost_dict(compiled).items()
                   if k in ("flops", "transcendentals")}
    rec["memory"] = _mem_dict(compiled)
    # trip-count-aware per-device cost (the roofline source of truth)
    rec["hlo"] = hlo_cost.analyze(compiled.as_text())
    rec["collectives"] = rec["hlo"]["collectives"]
    rec["lower_compile_seconds"] = round(time.time() - t0, 1)
    return rec


def run_cell(arch, shape_name, multi_pod, force=False):
    sub = "pod512" if multi_pod else "pod256"
    outdir = RESULTS / sub
    outdir.mkdir(parents=True, exist_ok=True)
    fn = outdir / f"{arch.replace('/', '_')}__{shape_name}.json"
    if fn.exists() and not force:
        print(f"[dryrun] SKIP (cached) {arch} x {shape_name} [{sub}]")
        return json.loads(fn.read_text())
    print(f"[dryrun] {arch} x {shape_name} [{sub}] ...", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod)
        status = "skipped" if rec.get("skipped") else "ok"
    except Exception as e:  # noqa: BLE001
        rec = {"arch": arch, "shape": shape_name, "error": str(e)[-4000:],
               "traceback": traceback.format_exc()[-8000:]}
        status = "FAIL"
    fn.write_text(json.dumps(rec, indent=1))
    secs = rec.get("lower_compile_seconds", 0)
    print(f"[dryrun] {status} {arch} x {shape_name} [{sub}] ({secs}s)",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                run_cell(arch, shape, args.multi_pod, args.force)
    else:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.force)
        if "error" in rec:
            print(rec["traceback"])
            raise SystemExit(1)
        print(json.dumps({k: v for k, v in rec.items()
                          if k != "traceback"}, indent=1))


if __name__ == "__main__":
    main()
