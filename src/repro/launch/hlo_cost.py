"""HLO-text cost analysis with while-loop trip-count awareness.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body **once**,
so any scan-over-layers program (ours — and every production LM trainer)
under-reports flops/bytes/collectives by ~n_layers.  This walker parses the
optimized HLO text, computes per-computation costs, and rolls them up through
the call graph multiplying ``while`` bodies by their ``known_trip_count``.

Cost model (mirrors HloCostAnalysis):
  * dot: 2 x prod(result dims) x prod(contracting dims)
  * elementwise/reduce ops: 1 flop per output element (transcendentals
    tracked separately)
  * bytes: operands + result per instruction; fusions count only their
    call-site operands/result; parameter/tuple/GTE/bitcast free;
    (dynamic-)slice/update-slice count the touched sub-region only
  * collectives: per-device moved bytes — result size (x2 for all-reduce,
    x group for reduce-scatter), multiplied by enclosing trip counts
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[a-z0-9_\[\]{},]+))\s+([\w\-]+)\(")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\D*(\d+)')
_GROUP_RE = re.compile(r"replica_groups=(?:\{\{([0-9,]+)\}|\[(\d+),(\d+)\])")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "convert", "floor", "ceil", "round-nearest-afz", "clamp", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "popcnt",
    "reduce", "reduce-window", "iota", "broadcast", "reverse", "pad",
    "concatenate", "transpose", "copy", "reshape",
}
TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                  "sine", "cosine", "logistic", "expm1", "log1p", "atan2",
                  "erf", "cbrt"}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
FREE = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
        "after-all", "partition-id", "replica-id", "opt-barrier",
        "get-dimension-size"}


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Cost:
    __slots__ = ("flops", "transcendentals", "bytes", "bytes_min", "coll",
                 "coll_sites")

    def __init__(self):
        self.flops = 0.0
        self.transcendentals = 0.0
        self.bytes = 0.0
        # bytes under a TPU-like perfect-elementwise-fusion model: only
        # dots/convs/collectives/slice-ops touch HBM
        self.bytes_min = 0.0
        self.coll = defaultdict(float)
        self.coll_sites = defaultdict(float)   # "kind shape" -> moved bytes

    def add(self, other: "Cost", mult: float = 1.0, with_bytes: bool = True):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        if with_bytes:
            self.bytes += other.bytes * mult
        self.bytes_min += other.bytes_min * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult
        for k, v in other.coll_sites.items():
            self.coll_sites[k] += v * mult


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$", line)
            if m:
                cur = m.group(1)
                body = []
                if line.rstrip().endswith("}"):
                    comps[cur] = []
                    cur = None
        else:
            if stripped == "}" or stripped.startswith("} //"):
                comps[cur] = body
                cur = None
            else:
                body.append(line)
    return comps


def _operands(rest: str) -> List[str]:
    """Operand names from the op's (...) argument list.

    Operands are split on top-level commas only — commas inside layout
    braces (``{1,0}``), shape brackets (``[2,3]``) or nested parens (tuple
    types) are part of the operand.  Each operand may be just ``%name`` or a
    typed ``f32[2,3]{1,0} %name``; the ``%``-token is the name.
    """
    i = rest.find("(")
    if i < 0:
        return []
    depth = 0       # () nesting; splitting happens at depth 1
    brack = 0       # {} / [] nesting; commas inside are not separators
    out = []
    tok = []
    for ch in rest[i:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                if tok:
                    out.append("".join(tok).strip())
                break
        if depth >= 1:
            if ch in "{[":
                brack += 1
            elif ch in "}]":
                brack -= 1
            if ch == "," and depth == 1 and brack == 0:
                out.append("".join(tok).strip())
                tok = []
            else:
                tok.append(ch)
    names = []
    for o in out:
        for piece in o.split():
            if piece.startswith("%"):
                names.append(piece.lstrip("%"))
                break
    return names


def analyze(text: str, entry: Optional[str] = None) -> Dict[str, float]:
    comps = _split_computations(text)
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        entry_name = m.group(1) if m else next(iter(comps))

    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        cost = Cost()
        memo[name] = cost
        shapes: Dict[str, str] = {}
        for line in comps.get(name, ()):
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            vname, rest = dm.groups()
            om = _OP_RE.match(rest)
            if not om:
                continue
            type_str, opcode = om.groups()
            shapes[vname] = type_str
            if opcode in FREE or opcode.endswith("-done"):
                continue

            # --- nested calls ---
            if opcode == "while":
                cm = _CALL_ATTR_RE.search(rest)
                trip = 1
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = int(tm.group(1))
                bm = re.search(r"body=%?([\w\.\-]+)", rest)
                if bm:
                    cost.add(comp_cost(bm.group(1)), trip)
                cnd = re.search(r"condition=%?([\w\.\-]+)", rest)
                if cnd:
                    cost.add(comp_cost(cnd.group(1)), trip)
                continue
            if opcode == "conditional":
                bm = _COND_BRANCH_RE.search(rest)
                if bm:
                    branches = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",")]
                    sub = [comp_cost(b) for b in branches]
                    if sub:
                        best = max(sub, key=lambda c: c.flops + c.bytes)
                        cost.add(best)
                continue
            if opcode in ("fusion", "call", "custom-call", "map", "sort",
                          "reduce", "reduce-window", "scatter",
                          "select-and-scatter", "all-reduce"):
                cm = _CALL_ATTR_RE.search(rest)
                if cm and opcode in ("call", "map"):
                    cost.add(comp_cost(cm.group(1)))
                elif cm and opcode == "fusion":
                    # fusion body: count inner flops/collectives, but bytes
                    # only at the fusion boundary (call-site operands/result)
                    cost.add(comp_cost(cm.group(1)), with_bytes=False)

            # --- collectives ---
            matched_coll = None
            for ck in COLLECTIVES:
                if opcode == ck or opcode == ck + "-start":
                    matched_coll = ck
                    break
            if matched_coll:
                size = shape_bytes(type_str)
                factor = 2.0 if matched_coll == "all-reduce" else 1.0
                if matched_coll == "reduce-scatter":
                    gm = _GROUP_RE.search(rest)
                    if gm:
                        if gm.group(1):
                            factor = len(gm.group(1).split(","))
                        elif gm.group(2):
                            factor = int(gm.group(2))
                cost.coll[matched_coll] += size * factor
                cost.coll["count"] += 1
                sm = _SHAPE_RE.search(type_str)
                key = f"{matched_coll} {sm.group(0) if sm else '?'}"
                cost.coll_sites[key] += size * factor
                cost.bytes += shape_bytes(type_str)
                cost.bytes_min += shape_bytes(type_str)
                continue

            # --- flops ---
            if opcode == "dot":
                dims = _shape_dims(type_str)
                out_elems = 1
                for d in dims:
                    out_elems *= d
                ops = _operands(rest)
                lhs_shape = shapes.get(ops[0], "") if ops else ""
                cm_ = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                contract = 1
                if cm_ and lhs_shape:
                    ldims = _shape_dims(lhs_shape)
                    for idx in cm_.group(1).split(","):
                        if idx and int(idx) < len(ldims):
                            contract *= ldims[int(idx)]
                cost.flops += 2.0 * out_elems * contract
            elif opcode == "convolution":
                cost.flops += 2.0 * shape_elems(type_str)  # stub convs only
            elif opcode in TRANSCENDENTAL:
                n = shape_elems(type_str)
                cost.flops += n
                cost.transcendentals += n
            elif opcode in ELEMENTWISE or opcode.startswith("rng"):
                cost.flops += shape_elems(type_str)

            # --- bytes ---
            if opcode in ("dynamic-update-slice",):
                ops = _operands(rest)
                upd = shapes.get(ops[1], type_str) if len(ops) > 1 else type_str
                cost.bytes += 2 * shape_bytes(upd)
                cost.bytes_min += 2 * shape_bytes(upd)
            elif opcode in ("dynamic-slice", "slice", "gather"):
                cost.bytes += 2 * shape_bytes(type_str)
                cost.bytes_min += 2 * shape_bytes(type_str)
            else:
                ops = _operands(rest)
                b = shape_bytes(type_str)
                for o in ops:
                    b += shape_bytes(shapes.get(o, ""))
                cost.bytes += b
                if opcode in ("dot", "convolution", "scatter"):
                    cost.bytes_min += b
        return cost

    total = comp_cost(entry_name)
    out = {"flops": total.flops, "bytes": total.bytes,
           "bytes_min": total.bytes_min,
           "transcendentals": total.transcendentals,
           "collectives": dict(total.coll)}
    out["collectives"]["total"] = sum(
        v for k, v in total.coll.items() if k != "count")
    out["top_collectives"] = dict(
        sorted(total.coll_sites.items(), key=lambda kv: -kv[1])[:20])
    return out
