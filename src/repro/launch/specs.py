"""ShapeDtypeStruct stand-ins for every model input — shardable,
weak-type-correct, zero device allocation.  The dry-run lowers against
these; real launchers build identically-sharded concrete arrays."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import params as PM
from repro.models.lm import LM
from repro.utils.sharding import axis_size, batch_axes


def _sds(shape, dtype, mesh, spec):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _batch_spec(mesh, b: int):
    ba = batch_axes(mesh)
    if mesh is not None and b % axis_size(mesh, ba) == 0:
        return P(ba)
    return P(None)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict:
    b, t = shape.global_batch, shape.seq_len
    bs = _batch_spec(mesh, b)
    out = {
        "tokens": _sds((b, t), jnp.int32, mesh, P(*bs, None)),
        "labels": _sds((b, t), jnp.int32, mesh, P(*bs, None)),
    }
    if cfg.frontend == "patch":
        out["images"] = _sds((b, cfg.image_size, cfg.image_size,
                              cfg.image_channels), jnp.float32, mesh,
                             P(*bs, None, None, None))
    if cfg.frontend == "audio":
        out["mels"] = _sds((b, 2 * cfg.encoder_seq, cfg.n_mels),
                           jnp.float32, mesh, P(*bs, None, None))
    return out


def decode_inputs(model: LM, shape: ShapeConfig, mesh) -> Tuple[Any, Any, Any]:
    """(cache, tokens, pos) abstract inputs for a serve_step lowering."""
    b = shape.global_batch
    cache = PM.abstract(model.cache_defs(b, shape.seq_len), mesh=mesh)
    bs = _batch_spec(mesh, b)
    tokens = _sds((b, 1), jnp.int32, mesh, P(*bs, None))
    pos = _sds((), jnp.int32, mesh, P())
    return cache, tokens, pos


def rng_spec(mesh):
    return _sds((2,), jnp.uint32, mesh, P())


def input_specs(model: LM, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """Everything the dry-run needs for one (arch x shape) cell."""
    cfg = model.cfg
    if shape.kind == "train":
        return {"kind": "train",
                "batch": train_batch_specs(cfg, shape, mesh),
                "rng": rng_spec(mesh)}
    if shape.kind == "prefill":
        return {"kind": "prefill",
                "batch": train_batch_specs(cfg, shape, mesh)}
    cache, tokens, pos = decode_inputs(model, shape, mesh)
    return {"kind": "decode", "cache": cache, "tokens": tokens, "pos": pos}
