"""Training launcher.

CPU-scale (this container):
  python -m repro.launch.train --arch llama3.2-1b --reduced --steps 20 \
      --global_batch 8 --seq 64

Any registered optimizer races through the same trainer loop:
  python -m repro.launch.train --arch llama3.2-1b --reduced \
      --optimizer adam --lr 1e-3

TPU-pod scale (real deployment): drop --reduced, pass --mesh production
[--multi_pod]; the same code paths lower onto the 16x16 / 2x16x16 meshes the
dry-run validates.
"""
from __future__ import annotations

import argparse

import jax

from repro import obs as obs_mod
from repro import optimizers
from repro.configs import get_config, get_reduced_config
from repro.configs.base import KFACConfig, ObsConfig, TrainConfig
from repro.data.pipeline import (SyntheticLMData, make_audio_batch,
                                 make_vlm_batch)
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.lm import LM
from repro.training.checkpoint import Checkpointer
from repro.training.trainer import Trainer


class _ArchData:
    """Wraps the token pipeline with the arch's raw modality inputs
    (images / mel frames; the model's own conv stems embed them)."""

    def __init__(self, cfg, base):
        self.cfg, self.base = cfg, base

    def batch(self, step):
        b = self.base.batch(step)
        if self.cfg.frontend == "patch":
            b = make_vlm_batch(b, self.cfg.image_size,
                               self.cfg.image_channels, self.base.mesh, step)
        if self.cfg.frontend == "audio":
            b = make_audio_batch(b, self.cfg.n_mels,
                                 2 * self.cfg.encoder_seq, self.base.mesh,
                                 step)
        return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global_batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", choices=["none", "local", "production"],
                    default="none")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--optimizer", default="kfac",
                    choices=["kfac", "sgd_momentum", "adam"])
    ap.add_argument("--lr", type=float, default=1e-3,
                    help="learning rate for the first-order baselines")
    ap.add_argument("--lambda_init", type=float, default=10.0)
    ap.add_argument("--inv_mode", default="blkdiag",
                    choices=["blkdiag", "tridiag", "eigen"])
    ap.add_argument("--refresh_mode", default="serial",
                    choices=["serial", "staggered", "sharded", "overlap"],
                    help="how the T3 inverse refresh executes: serially, "
                         "staggered over T3 steps, block-parallel over the "
                         "mesh, or asynchronously double-buffered "
                         "(repro.distributed; docs/distributed.md)")
    ap.add_argument("--tau1", type=float, default=1.0)
    ap.add_argument("--obs", action="store_true",
                    help="enable telemetry: per-step/stage timings, "
                         "refresh events, end-of-run snapshot "
                         "(docs/observability.md)")
    ap.add_argument("--obs_jsonl", default="",
                    help="JSONL event log path (implies --obs)")
    ap.add_argument("--obs_console_every", type=int, default=0,
                    help="print the telemetry snapshot every N steps")
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    mesh = {"none": None, "local": make_local_mesh(),
            "production": lambda: make_production_mesh(
                multi_pod=args.multi_pod)}[args.mesh]
    if callable(mesh):
        mesh = mesh()

    # one shared Obs across the optimizer pipeline and the trainer: the
    # kfac_step / refresh events and the train_step events land in one
    # registry and one JSONL log
    ocfg = ObsConfig(enabled=args.obs or bool(args.obs_jsonl),
                     jsonl_path=args.obs_jsonl,
                     console_every=args.obs_console_every)
    obs = obs_mod.Obs(ocfg)

    kcfg = KFACConfig(lambda_init=args.lambda_init, inv_mode=args.inv_mode,
                      refresh_mode=args.refresh_mode, tau1=args.tau1, t3=5,
                      obs=ocfg)
    tcfg = TrainConfig(steps=args.steps,
                       checkpoint_dir=args.ckpt_dir or "/tmp/repro_ckpt",
                       checkpoint_every=max(10, args.steps // 2),
                       obs=ocfg)
    lm = LM(cfg, kcfg, mesh)
    opt = (optimizers.kfac(lm, kcfg, mesh, obs=obs)
           if args.optimizer == "kfac"
           else optimizers.get(args.optimizer, lm, lr=args.lr))
    params = lm.init_params(jax.random.PRNGKey(0))
    print(f"[train] arch={cfg.name} params={lm.n_params():,} "
          f"optimizer={opt.name}")

    data = _ArchData(cfg, SyntheticLMData(cfg.vocab_size, args.seq,
                                          args.global_batch, mesh))
    ckpt = Checkpointer(tcfg.checkpoint_dir) if args.ckpt_dir else None
    trainer = Trainer(lm, opt, tcfg, mesh, ckpt, obs=obs)
    result = trainer.fit(params, data, args.steps)
    hist = result["history"]
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}"
          f" in {result['seconds']:.1f}s")
    if obs.enabled:
        # the end-of-run stats line IS the obs snapshot — one formatting
        # path (repro.obs.export.console_summary) for every launcher
        print(obs.summary(title="train"))
        obs.close()
    return result


if __name__ == "__main__":
    main()
