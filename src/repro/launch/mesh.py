"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) — `pod` carries only
DCN-friendly gradient/statistics reductions; FSDP all-gathers stay on the
in-pod ICI `data` axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a 1D (data,) mesh — CPU tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
