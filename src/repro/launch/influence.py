"""Offline influence-function queries over an exported curvature bundle.

  # end-to-end demo: train a small MLP under EKFAC, export a bundle,
  # reload it optimizer-free and attribute a query example
  python -m repro.launch.influence --steps 20 --topk 5

  # query an existing training-exported bundle
  python -m repro.launch.influence --bundle /tmp/ckpt/curvature/step_00000100

The attribution is the EKFAC-approximated Koh & Liang form: for query
example ``z_q`` and training example ``z_i``,

    I(z_i, z_q) = <grad L(z_q), (F + lambda I)^{-1} grad L(z_i)>

computed by :class:`repro.curvature.InfluenceEngine` (one iHVP per query,
dotted against per-example training gradients).  Positive scores mark
training examples whose own gradient direction *helps* the query
(memorization probes, data attribution); ``--export`` keeps the bundle
around for serving (``launch/serve.py --uncertainty --bundle ...``).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KFACConfig
from repro.curvature import (InfluenceEngine, load_bundle, per_example_grads,
                             save_bundle, snapshot_bundle)
from repro.models.mlp import MLP
from repro.optimizers.kfac import kfac


def _train_bundle(args):
    """Train the demo MLP a few EKFAC steps and snapshot its curvature."""
    dims = [int(d) for d in args.dims.split(",")]
    mlp = MLP(dims, loss="bernoulli")
    params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)
    x = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5,
                             (args.batch, dims[0])).astype(jnp.float32)
    batch = {"x": x, "y": x[:, :dims[-1]]}
    opt = kfac(mlp, KFACConfig(inv_mode="eigen", t3=5, lambda_init=3.0))
    state = opt.init(params, batch)
    for step in range(args.steps):
        params, state, metrics = opt.update(
            None, state, params, batch,
            jax.random.fold_in(jax.random.PRNGKey(2), step))
    print(f"[influence] trained {args.steps} steps, "
          f"loss={float(metrics['loss']):.4f}")
    return mlp, params, batch, snapshot_bundle(opt.engine, state)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bundle", default=None,
                    help="load this bundle instead of training the demo")
    ap.add_argument("--steps", type=int, default=20,
                    help="EKFAC training steps for the demo bundle")
    ap.add_argument("--dims", default="8,16,4",
                    help="demo MLP layer dims (comma-separated)")
    ap.add_argument("--batch", type=int, default=64,
                    help="training batch = the attribution candidate set")
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--query", type=int, default=0,
                    help="index of the batch row used as the query example")
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas"),
                    help="route for the iHVP middle contraction")
    ap.add_argument("--extra_damping", type=float, default=0.0,
                    help="extra lambda added at query time (no re-export)")
    ap.add_argument("--export", default=None,
                    help="also save the demo bundle at this path")
    args = ap.parse_args(argv)

    if args.bundle is not None:
        bundle = load_bundle(args.bundle)
        print(f"[influence] loaded bundle step={bundle.step} "
              f"blocks={bundle.block_names} lam={bundle.lam:.3g}")
        # engine-free loading means no model: restrict to self-influence
        eng = InfluenceEngine(bundle, backend=args.backend,
                              extra_damping=args.extra_damping)
        print("[influence] bundle-only mode: pass no --bundle for the "
              "trained-demo attribution query (needs the model for grads)")
        return eng

    mlp, params, batch, bundle = _train_bundle(args)
    if args.export:
        save_bundle(bundle, args.export)
        print(f"[influence] bundle exported -> {args.export}")
    eng = InfluenceEngine(bundle, backend=args.backend,
                          extra_damping=args.extra_damping)

    grads = per_example_grads(mlp, params, batch)
    query = jax.tree.map(lambda a: a[args.query], grads)
    scores = np.asarray(eng.influence(query, grads))
    vals, idx = eng.topk(jnp.asarray(scores), args.topk)
    print(f"[influence] query=row {args.query}: "
          f"top-{args.topk} influential training rows")
    for rank, (i, v) in enumerate(zip(np.asarray(idx), np.asarray(vals))):
        marker = " (self)" if int(i) == args.query else ""
        print(f"  #{rank + 1}: row {int(i)}  score={float(v):+.4e}{marker}")
    si = np.asarray(eng.self_influence(grads))
    print(f"[influence] self-influence: mean={si.mean():.4e} "
          f"max=row {int(si.argmax())} ({si.max():.4e})")
    return scores


if __name__ == "__main__":
    main()
