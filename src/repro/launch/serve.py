"""Serving launcher: continuous batching over the paged-KV engine.

  python -m repro.launch.serve --arch smollm-135m --reduced --requests 6
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_reduced_config
from repro.models.lm import LM
from repro.serving.server import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_len", type=int, default=128)
    ap.add_argument("--max_new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    eng = Engine(lm, params, batch_slots=args.slots, max_len=args.max_len)
    reqs = [Request(uid=i, prompt=[(7 * i + j) % cfg.vocab_size
                                   for j in range(4 + i % 3)],
                    max_new=args.max_new, temperature=0.0 if i % 2 else 0.8)
            for i in range(args.requests)]
    rep = eng.run(reqs)
    for r in reqs:
        print(f"[serve] req {r.uid}: prompt={r.prompt} -> out={r.out}")
    assert all(r.done or r.out for r in reqs)
    print(f"[serve] {rep.steps} steps: {len(rep.completed)} completed, "
          f"{len(rep.unfinished)} in flight, {len(rep.unserved)} queued, "
          f"{len(rep.failed)} rejected")
    return rep


if __name__ == "__main__":
    main()
