"""Serving launcher: continuous batching over the paged-KV engine.

  python -m repro.launch.serve --arch smollm-135m --reduced --requests 6 \\
      --temperature 0.8 --top_k 40 --seed 7

The default decode route is block-indexed paged attention
(``--decode_route gather`` selects the dense-gather oracle for debugging);
``--num_pages`` shrinks the page pool to exercise eviction/preemption.

``--uncertainty`` requests per-token Laplace predictive variance: pass
``--bundle <path>`` to load a training-exported curvature bundle
(``docs/influence.md``), or omit it to build an identity bundle from the
model's own block registry (fresh zero factors — a smoke-test posterior,
not a trained one).  Uncertainty stats print only when requested; without
the flag the engine and its outputs are identical to before.
"""
from __future__ import annotations

import argparse

import jax

from repro import obs as obs_mod
from repro.configs import get_config, get_reduced_config
from repro.models.lm import LM
from repro.obs import ObsConfig
from repro.serving.server import DECODE_ROUTES, Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_len", type=int, default=128)
    ap.add_argument("--max_new", type=int, default=8)
    ap.add_argument("--decode_route", choices=DECODE_ROUTES, default="paged")
    ap.add_argument("--page_size", type=int, default=8)
    ap.add_argument("--num_pages", type=int, default=None,
                    help="page pool size; small values force "
                         "eviction/preemption under load")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples")
    ap.add_argument("--top_k", type=int, default=0)
    ap.add_argument("--top_p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=None,
                    help="per-request sampling seed base (request i uses "
                         "seed+i); omit for the engine-shared RNG")
    ap.add_argument("--uncertainty", action="store_true",
                    help="request per-token Laplace predictive variance")
    ap.add_argument("--bundle", default=None,
                    help="curvature bundle path (with --uncertainty); "
                         "omit for an identity smoke-test bundle")
    ap.add_argument("--obs", action="store_true",
                    help="enable telemetry: queue/slot/page gauges, "
                         "TTFT & decode-gap histograms, JSONL events "
                         "(docs/observability.md)")
    ap.add_argument("--obs_jsonl", default="",
                    help="JSONL event log path (implies --obs)")
    args = ap.parse_args(argv)

    obs = obs_mod.Obs(ObsConfig(enabled=args.obs or bool(args.obs_jsonl),
                                jsonl_path=args.obs_jsonl))

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    laplace = _build_laplace(lm, args) if args.uncertainty else None
    eng = Engine(lm, params, batch_slots=args.slots, max_len=args.max_len,
                 page_size=args.page_size, num_pages=args.num_pages,
                 decode_route=args.decode_route, laplace=laplace, obs=obs)
    reqs = [Request(uid=i, prompt=[(7 * i + j) % cfg.vocab_size
                                   for j in range(4 + i % 3)],
                    max_new=args.max_new, temperature=args.temperature,
                    top_k=args.top_k, top_p=args.top_p,
                    seed=None if args.seed is None else args.seed + i,
                    uncertainty=args.uncertainty)
            for i in range(args.requests)]
    rep = eng.run(reqs)
    for r in reqs:
        tag = f" (preempted x{r.preemptions})" if r.preemptions else ""
        if args.uncertainty and r.var:
            tag += (f" var[{min(r.var):.3g}..{max(r.var):.3g}]"
                    f" mean={sum(r.var) / len(r.var):.3g}")
        print(f"[serve] req {r.uid}: prompt={r.prompt} -> out={r.out}{tag}")
    assert all(r.done or r.out for r in reqs)
    print(f"[serve] {rep.steps} steps ({args.decode_route} route): "
          f"{len(rep.completed)} completed, "
          f"{len(rep.unfinished)} in flight, {len(rep.unserved)} queued, "
          f"{len(rep.failed)} rejected")
    # the stats line renders from the obs registry — the engine's always-
    # live counters — through the one shared formatting path
    print(obs.summary(title="serve"))
    if obs.enabled and rep.ttft_p50_ms is not None:
        print(f"[serve] ttft p50={rep.ttft_p50_ms:.2f}ms "
              f"p99={rep.ttft_p99_ms:.2f}ms")
    if args.uncertainty and rep.mean_token_variance is not None:
        print(f"[serve] mean per-token Laplace variance: "
              f"{rep.mean_token_variance:.4g}")
    obs.close()
    return rep


def _build_laplace(lm, args):
    """The Laplace head for --uncertainty: a trained bundle from disk, or
    an identity bundle (zero factors, gamma=1) as a smoke-test stand-in."""
    from repro.curvature import CurvatureBundle, LaplaceHead, load_bundle

    if args.bundle is not None:
        return LaplaceHead(load_bundle(args.bundle))
    from repro.configs.base import KFACConfig
    from repro.core.blocks import build_blocks

    name = "lm_head" if "lm_head" in lm.metas else "embed"
    meta = lm.metas[name]
    blk = build_blocks({name: meta}, KFACConfig())[name]
    eig = blk.eigen_state(blk.init_factors(), 1.0)
    return LaplaceHead(CurvatureBundle(
        step=0, lam=1.0, gamma=1.0, eta=0.0,
        metas={name: meta}, eigen={name: eig}))


if __name__ == "__main__":
    main()
