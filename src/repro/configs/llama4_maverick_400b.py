"""llama4-maverick-400b-a17b [moe]: MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    moe_every=2,                  # MoE every other layer (maverick layout)
    moe_shared_expert=True,       # llama4 routes top-1 + a shared expert
    rope_theta=500_000.0,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama4-maverick-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=256, n_experts=4,
    )
