"""Architecture registry: ``--arch <id>`` ids map to config modules here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    KFACConfig,
    MeshConfig,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    TrainConfig,
)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "yi-34b": "yi_34b",
    "smollm-135m": "smollm_135m",
    "gemma2-2b": "gemma2_2b",
    "llama3.2-1b": "llama3_2_1b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "whisper-small": "whisper_small",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells():
    """All (arch, shape) dry-run cells, including skip annotations."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            out.append((arch, sname, sname in cfg.skip_shapes))
    return out
