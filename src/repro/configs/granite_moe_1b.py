"""granite-moe-1b-a400m [moe]: 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    moe_every=1,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=256, n_experts=4,
        top_k=2,
    )
