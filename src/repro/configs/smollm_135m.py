"""smollm-135m [dense]: llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="smollm-135m-reduced", n_layers=2, d_model=48, n_heads=3,
        n_kv_heads=1, head_dim=16, d_ff=96, vocab_size=256,
    )
