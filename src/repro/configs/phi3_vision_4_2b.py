"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + real Conv2D patch frontend.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision frontend is no longer a stub: ``input_specs()`` provides raw
images of shape (batch, image_size, image_size, channels) and the model's
own Conv2D patchifier (kernel = stride = patch_size, KFC-tagged and
preconditioned by ``ConvKronecker``) embeds them into
``(image_size/patch_size)²`` patch tokens, fused (early fusion) with the
token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    frontend="patch",
    frontend_tokens=576,          # 24x24 CLIP-style patch grid
    image_size=336,
    patch_size=14,
    image_channels=3,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="phi-3-vision-4.2b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        frontend_tokens=4, image_size=8, patch_size=4,
    )
