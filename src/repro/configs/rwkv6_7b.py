"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                   # rwkv time-mix heads (d_model / rwkv_head_dim)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    attn_free=True,
    rwkv_head_dim=64,
    # recurrent: O(1) state per decoded token -> long_500k runs
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="rwkv6-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, rwkv_head_dim=16,
    )
