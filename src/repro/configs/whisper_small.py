"""whisper-small [audio]: enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

Backbone only: the conv/mel frontend is a STUB — input_specs() provides
precomputed frame embeddings of shape (batch, encoder_seq, d_model).
n_layers counts decoder layers; encoder_layers the (full-attention) encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    frontend="audio",
    frontend_tokens=1500,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-small-reduced", n_layers=2, encoder_layers=2,
        encoder_seq=16, d_model=48, n_heads=3, n_kv_heads=3, head_dim=16,
        d_ff=96, vocab_size=256, frontend_tokens=16,
    )
