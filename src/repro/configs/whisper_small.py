"""whisper-small [audio]: enc-dec with the real Conv1D mel stem.
[arXiv:2212.04356; unverified]

The encoder frontend is no longer a stub: ``input_specs()`` provides raw
log-mel frames of shape (batch, 2*encoder_seq, n_mels) and the model's own
two-layer Conv1D stem (k=3 s=1 then k=3 s=2, GELU after each) embeds and
2x-downsamples them to (batch, encoder_seq, d_model).  Both convs are
K-FAC-tagged and preconditioned by ``ConvKronecker`` (KFC, 1602.01407).
n_layers counts decoder layers; encoder_layers the (full-attention) encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    frontend="audio",
    frontend_tokens=1500,
    n_mels=80,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-small-reduced", n_layers=2, encoder_layers=2,
        encoder_seq=16, d_model=48, n_heads=3, n_kv_heads=3, head_dim=16,
        d_ff=96, vocab_size=256, frontend_tokens=16, n_mels=8,
    )
