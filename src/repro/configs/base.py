"""Config dataclasses for models, shapes, K-FAC, mesh and training.

Everything in the framework is driven by these frozen dataclasses; the
per-architecture modules in this package each export a ``CONFIG`` constant
plus a ``reduced()`` helper used by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

from repro.obs.config import ObsConfig


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (transformer backbone families).

    ``family`` is one of: dense | moe | hybrid | ssm | vlm | audio.
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # --- attention variants ---
    attn_free: bool = False           # rwkv6: no attention at all
    sliding_window: int = 0           # gemma2: local window size for odd layers
    alt_local_global: bool = False    # gemma2: alternate local/global attention
    logit_softcap: float = 0.0        # gemma2 final-logit soft cap
    attn_softcap: float = 0.0         # gemma2 attention-score soft cap
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                # MoE layer every N layers (others dense)
    moe_shared_expert: bool = False   # llama4-style shared expert alongside routed

    # --- hybrid (jamba) / ssm ---
    attn_every: int = 0               # jamba: 1 attention layer per this many
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2

    # --- rwkv6 ---
    rwkv_head_dim: int = 64

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0           # >0 -> enc-dec; n_layers = decoder layers
    encoder_seq: int = 1500           # number of (stubbed) audio frames

    # --- modality frontends (real conv stems, KFC-preconditioned) ---
    frontend: str = "none"            # none | patch | audio
    frontend_tokens: int = 0          # patch/frame token count after the stem
    n_mels: int = 80                  # audio: log-mel channels into the
                                      # Conv1D stem (k=3 s=1, then k=3 s=2)
    image_size: int = 0               # patch: square input image side
    patch_size: int = 0               # patch: Conv2D patchifier kernel=stride
    image_channels: int = 3           # patch: input image channels

    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq: int = 540_672

    # which shapes this arch supports (subset of SHAPES keys)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return (i % self.moe_every) == (self.moe_every - 1)

    def is_attn_layer(self, i: int) -> bool:
        """For hybrid archs, whether layer i is attention (else Mamba)."""
        if self.attn_free:
            return False
        if self.attn_every <= 1:
            return True
        # jamba: one attention layer per `attn_every` block, in the middle
        return (i % self.attn_every) == (self.attn_every // 2)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The assigned LM shape set; every (arch x shape) cell is well defined.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class KFACConfig:
    """The paper's optimizer hyper-parameters (section references in brackets)."""

    inv_mode: str = "blkdiag"         # blkdiag | tridiag      [S4.2 / S4.3]
                                      # | eigen (EKFAC, 1806.03884): amortized
                                      # factor eigenbases + per-step diagonal
    eigen_decay: float = 0.95         # eigen mode: EMA decay of the
                                      # eigenbasis second-moment diagonal s
    inverse_method: str = "ns"        # ns | eigh | solve      [S8 / App B]
    ns_iters: int = 12                # Newton-Schulz iterations (cold start)
    ns_hot_iters: int = 4             # when hot-started from previous inverse

    lambda_init: float = 150.0        # LM damping initial value  [S6.5]
    eta: float = 1e-5                 # l2 regularization coefficient [S13]
    t1: int = 5                       # lambda adaptation period  [S6.5]
    t2: int = 20                      # gamma adaptation period   [S6.6]
    t3: int = 20                      # inverse recompute period  [S8]
    omega1_base: float = 19.0 / 20.0  # lambda decay base         [S6.5]
    omega2_base: float = 19.0 / 20.0  # gamma decay base (sqrt)   [S6.6]

    decay_cap: float = 0.95           # epsilon = min(1 - 1/k, cap) [S5]
    tau1: float = 1.0                 # stats subsample fraction  [S8]
    tau2: float = 1.0                 # exact-F subsample fraction [S8]

    use_momentum: bool = True         # (alpha, mu) from exact-F 2x2 solve [S7]
    use_rescale: bool = True          # exact-F alpha rescale     [S6.4]
    fixed_lr: float = 0.05            # used only when use_rescale=False

    max_factor_dim: int = 8_192       # local dims above this -> diagonal factor
    factor_dtype: str = "float32"
    kernel_backend: str = "xla"       # xla | pallas: route dense blocks'
                                      # factor_update / precondition through
                                      # the Pallas kernels (ragged shapes
                                      # fall back to the einsum path)
    autotune: str = "off"             # off | cache | force: per-(kernel,
                                      # shape, dtype, backend) tile-size
                                      # autotuning for the Pallas kernels
                                      # (repro.kernels.autotune; "off" is
                                      # bitwise-identical to the untuned
                                      # path; REPRO_AUTOTUNE overrides)
    fused_stats: bool = False         # fold the factor statistics into the
                                      # stats pass itself: A contracted
                                      # in-forward, G via a custom-VJP
                                      # contraction in the backward — one
                                      # pass over activations/cotangents
                                      # instead of two (docs/kernels.md;
                                      # ignored under inv_mode="tridiag",
                                      # which needs the raw records)
    fixed_momentum: float = 0.0       # use_rescale=False only: heavy-ball
                                      # mu for the fused update chain
    clip_delta_norm: float = 0.0      # use_rescale=False only: global-norm
                                      # clip of the applied update (0 = off)
    kl_clip: float = 0.0              # use_rescale=False only: norm-constraint
                                      # max lr²·|Δᵀ∇| per step (0 = off)
    stats_period: int = 1             # update stats every N steps
    staggered_inverse: bool = False   # legacy alias for refresh_mode="staggered"
    refresh_mode: str = "serial"      # serial | staggered | sharded | overlap:
                                      # how the T3 inverse refresh is executed
                                      # (repro.distributed — staggered spreads
                                      # blocks over T3 steps, sharded
                                      # bin-packs them over the mesh, overlap
                                      # double-buffers the sharded refresh
                                      # asynchronously under a bounded
                                      # staleness counter; docs/distributed.md)
    overlap_deterministic: bool = False
                                      # overlap mode: commit buffer swaps only
                                      # at refresh-due steps instead of as
                                      # soon as is_ready — wall-clock stops
                                      # affecting the trajectory (golden runs)
    damping_floor: float = 1e-8
    obs: ObsConfig = field(default_factory=ObsConfig)
                                      # telemetry for the optimizer pipeline
                                      # (per-stage spans, refresh events;
                                      # repro.obs / docs/observability.md).
                                      # disabled = bitwise the
                                      # uninstrumented program

    def replace(self, **kw) -> "KFACConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def axes(self):
        if self.pod > 1:
            return ("pod", "data", "model")
        return ("data", "model")

    @property
    def shape(self):
        if self.pod > 1:
            return (self.pod, self.data, self.model)
        return (self.data, self.model)

    @property
    def n_devices(self):
        return self.pod * self.data * self.model


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    seed: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"              # none | block (per-layer remat policy)
    grad_accum: int = 1
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    curvature_every: int = 0          # export a curvature bundle at steps
                                      # divisible by this AND by
                                      # checkpoint_every (0 = never)
    obs: ObsConfig = field(default_factory=ObsConfig)
                                      # telemetry for the training loop
                                      # (per-step events, rejected-step
                                      # counters; repro.obs)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    kfac: KFACConfig = field(default_factory=KFACConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
