"""llama3.2-1b [dense]: small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama3.2-1b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    )
