"""jamba-1.5-large-398b [hybrid]: Mamba+attn 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,                  # MoE every other layer (jamba layout)
    attn_every=8,                 # 1 attention layer per 8 (1:7 mamba:attn)
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
    # hybrid: attention layers are O(cache) at decode; mamba O(1) -> long OK
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-reduced", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, n_experts=4, top_k=2,
        attn_every=2, ssm_state_dim=4, ssm_conv_dim=2,
    )
