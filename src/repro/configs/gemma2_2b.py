"""gemma2-2b [dense]: local+global alternating attention, logit softcap.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    alt_local_global=True,
    sliding_window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    tie_embeddings=True,
    # alternating layers still include full-attention (global) layers
    skip_shapes=("long_500k",),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-2b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, sliding_window=16,
    )
