"""Conv classifier config — the KFC experimental family (1602.01407 §5).

A small strided CNN + softmax head over synthetic class-template images
(:class:`repro.data.pipeline.SyntheticImageData`), consumed by
``repro.models.convnet.ConvNet``.  Like the autoencoder config this lives
outside the 10 assigned LM architectures: it is the tier-1 vehicle for the
``ConvKronecker`` curvature blocks (golden trajectories per ``inv_mode``,
kernel parity, property tests) without the cost of the full whisper/vision
frontends.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ConvClassifierConfig:
    name: str = "conv-classifier"
    image_size: int = 32
    channels: int = 3
    n_classes: int = 10
    # (out_channels, kernel, stride) per layer; "SAME" padding, strided
    # downsampling (no pooling — every parameter sits in a Kronecker block)
    conv: Tuple[Tuple[int, int, int], ...] = ((32, 3, 1), (32, 3, 2),
                                              (64, 3, 2))
    nonlin: str = "relu"


CONFIG = ConvClassifierConfig()


def reduced() -> ConvClassifierConfig:
    return ConvClassifierConfig(name="conv-classifier-reduced",
                                image_size=8, channels=2, n_classes=4,
                                conv=((8, 3, 1), (8, 3, 2)), nonlin="relu")
