"""The paper's own benchmark family (S13): deep autoencoders in the style of
Hinton & Salakhutdinov (2006).  Used by the paper-fidelity experiments; this is
an MLP, not an LM, so it lives outside the 10 assigned architectures and is
consumed directly by `repro.models.mlp` / `examples/autoencoder_kfac.py`.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class AutoencoderConfig:
    name: str = "mnist-autoencoder"
    # MNIST autoencoder from Hinton & Salakhutdinov (2006) as used in S13
    encoder: Tuple[int, ...] = (784, 1000, 500, 250, 30)
    # decoder mirrors the encoder
    nonlin: str = "tanh"          # paper networks use tanh/logistic units
    loss: str = "bernoulli"       # cross-entropy reconstruction


CONFIG = AutoencoderConfig()


def reduced() -> AutoencoderConfig:
    return AutoencoderConfig(name="autoencoder-reduced",
                             encoder=(64, 32, 16, 8), nonlin="tanh",
                             loss="bernoulli")
