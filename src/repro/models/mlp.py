"""MLP / deep-autoencoder models — the paper's own experimental family (S13).

Faithful to the paper's setup: homogeneous coordinates (``ā = [a; 1]`` so the
bias is the last row of each W), tanh units, Bernoulli (cross-entropy)
reconstruction loss.  Every layer gets full two-sided Kronecker factors, and
the chain structure supports the **block-tridiagonal** inverse approximation
(S4.3) — consecutive-layer cross moments ``Ā_{i,i+1}``, ``G_{i,i+1}`` are
recorded alongside the diagonal ones.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.autoencoder import AutoencoderConfig
from repro.core.tags import LayerMeta, Tagger
from repro.models import params as PM


def autoencoder_dims(cfg: AutoencoderConfig) -> List[int]:
    enc = list(cfg.encoder)
    return enc + enc[-2::-1]          # mirror decoder


class MLP:
    """Feed-forward net with K-FAC tags.  dims = [d0, d1, ..., dL]."""

    def __init__(self, dims: List[int], nonlin: str = "tanh",
                 loss: str = "bernoulli", mesh=None):
        self.dims = list(dims)
        self.n_layers = len(dims) - 1
        self.nonlin = {"tanh": jnp.tanh, "relu": jax.nn.relu}[nonlin]
        self.loss_kind = loss
        self.mesh = mesh
        self.defs = {
            f"W{i}": PM.ParamDef((dims[i] + 1, dims[i + 1]), P(),
                                 init="normal")
            for i in range(self.n_layers)
        }
        self.metas: Dict[str, LayerMeta] = {
            f"layer{i}": LayerMeta(
                name=f"layer{i}", param_path=(f"W{i}",),
                d_in=dims[i], d_out=dims[i + 1], kind="dense",
                has_bias=True)
            for i in range(self.n_layers)
        }
        self.layer_order = [f"layer{i}" for i in range(self.n_layers)]
        self.contract_map = {}            # MLP records raw ā (cross moments)
        self.gcontract_map = {}           # fused_stats G-side hooks (core/fused)

    # -- params ---------------------------------------------------------
    def init_params(self, key, scale: float = None, sparse: bool = True):
        """Paper-style "sparse initialization" (Martens, 2010): each unit gets
        a limited number of nonzero incoming weights."""
        params = {}
        keys = jax.random.split(key, self.n_layers)
        for i in range(self.n_layers):
            d_in, d_out = self.dims[i], self.dims[i + 1]
            k1, k2 = jax.random.split(keys[i])
            w = jax.random.normal(k1, (d_in, d_out)) * (scale or 1.0)
            if sparse and d_in > 15:
                # keep 15 random connections per output unit
                idx = jax.vmap(
                    lambda k: jax.random.permutation(k, d_in) < 15)(
                        jax.random.split(k2, d_out)).T
                w = jnp.where(idx, w, 0.0)
            else:
                w = w / np.sqrt(d_in)
            b = jnp.zeros((1, d_out))
            params[f"W{i}"] = jnp.concatenate([w, b], axis=0)
        return params

    # -- forward --------------------------------------------------------
    def logits(self, params, x, tg: Optional[Tagger] = None):
        tg = tg or Tagger("plain")
        a = x
        for i in range(self.n_layers):
            ab = jnp.concatenate(
                [a, jnp.ones((*a.shape[:-1], 1), a.dtype)], axis=-1)
            s = ab @ params[f"W{i}"]
            s = tg.tag(f"layer{i}", ab, s)
            a = s if i == self.n_layers - 1 else self.nonlin(s)
        return a

    def _nll(self, z, y):
        if self.loss_kind == "bernoulli":
            # - sum_j [ y log sigmoid(z) + (1-y) log(1 - sigmoid(z)) ]
            return jnp.sum(jnp.logaddexp(0.0, z) - y * z, axis=-1)
        return 0.5 * jnp.sum((z - y) ** 2, axis=-1)    # gaussian

    def sample_targets(self, z, rng):
        if self.loss_kind == "bernoulli":
            return jax.random.bernoulli(rng, jax.nn.sigmoid(z)).astype(z.dtype)
        return z + jax.random.normal(rng, z.shape, z.dtype)

    def loss(self, params, probes, batch, rng, mode: str = "plain"):
        """Returns ((loss_true, loss_sampled), aux) — same contract as LM."""
        tg = Tagger(mode, probes, self.contract_map, self.gcontract_map)
        z = self.logits(params, batch["x"], tg)
        lt = jnp.mean(self._nll(z, batch["y"]))
        ys = self.sample_targets(jax.lax.stop_gradient(z), rng)
        ls = jnp.mean(self._nll(z, ys))
        return (lt, ls), {"recs": tg.out(), "metrics": {"loss": lt}}

    def probe_shapes(self, batch):
        def f(p, b):
            (lt, ls), aux = self.loss(p, None, b, jax.random.PRNGKey(0),
                                      mode="shapes")
            return aux["recs"]
        return jax.eval_shape(f, PM.abstract(self.defs), batch)

    def make_probes(self, shapes):
        return {k: jnp.zeros(v.shape, jnp.float32) for k, v in shapes.items()}

    def abstract_params(self, dtype=jnp.float32):
        return PM.abstract(self.defs, dtype, self.mesh)

    def n_params(self):
        return PM.count(self.defs)
