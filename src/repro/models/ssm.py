"""Mamba (selective SSM) block — jamba's non-attention layers.

Training/prefill uses a chunked associative scan: sequence split into chunks,
`lax.associative_scan` inside each chunk (log-depth, MXU-friendly), carry
state passed between chunks — bounding the (B, chunk, d_inner, N) transient.

Decode keeps {conv window, ssm state} and does one recurrence step.

K-FAC: in/x/dt/out projections are dense tags; the per-channel A_log / D / dt
bias vectors fall back to the diagonal Fisher (DESIGN §5).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.tags import Tagger
from repro.models.layers import dense

SSM_CHUNK = 256


def dt_rank(d_model: int) -> int:
    return max(1, -(-d_model // 16))  # ceil(d/16)


def _conv_shift(x, w, state=None):
    """Causal depthwise conv over T via shifts. x: (B,T,di); w: (K,di).

    state: (B, K-1, di) previous inputs for decode/chunk continuation.
    Returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)  # (B, K-1+T, di)
    y = sum(xx[:, j:j + x.shape[1], :] * w[j] for j in range(k))
    new_state = xx[:, -(k - 1):, :] if k > 1 else state
    return y, new_state


def _scan_chunked(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a, b: (B, T, di, N)."""
    bsz, t, di, n = a.shape
    c = min(chunk, t)
    while t % c:
        c -= 1
    nc = t // c
    a_ = a.reshape(bsz, nc, c, di, n).swapaxes(0, 1)
    b_ = b.reshape(bsz, nc, c, di, n).swapaxes(0, 1)

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    def body(h, xs):
        ac, bc = xs
        cum_a, s = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hc = s + cum_a * h[:, None]
        return hc[:, -1], hc

    hT, hs = jax.lax.scan(body, h0, (a_, b_))
    return hs.swapaxes(0, 1).reshape(bsz, t, di, n), hT


def mamba_block(tg: Tagger, name: str, p: Dict, x, state=None,
                *, ssm_state_dim: int, conv_dim: int, chunk: int = SSM_CHUNK,
                mesh=None) -> Tuple[jax.Array, Dict]:
    """x: (B, T, d). state: None (train/prefill from scratch) or
    {"conv": (B, K-1, di), "ssm": (B, di, N)} for decode continuation.
    Returns (y, new_state).
    """
    bsz, t, d = x.shape
    n = ssm_state_dim
    di = p["out_proj"].shape[0]
    r = p["dt_proj"].shape[0]

    xz = dense(tg, f"{name}.in_proj", p["in_proj"], x)          # (B,T,2*di)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _conv_shift(xi, p["conv_w"].astype(xi.dtype), conv_state)
    xi = jax.nn.silu(xi)

    dbc = dense(tg, f"{name}.x_proj", p["x_proj"], xi)          # (B,T,R+2N)
    dt_raw, bc, cc = jnp.split(dbc, [r, r + n], axis=-1)
    dt = dense(tg, f"{name}.dt_proj", p["dt_proj"], dt_raw)     # (B,T,di)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))            # (di,N)
    xif = xi.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * a_mat)                      # (B,T,di,N)
    drive = (dt * xif)[..., None] * bc.astype(jnp.float32)[:, :, None, :]

    h0 = (jnp.zeros((bsz, di, n), jnp.float32) if state is None
          else state["ssm"].astype(jnp.float32))
    if (mesh is not None and "model" in mesh.axis_names
            and di % mesh.shape["model"] == 0):
        # keep the (B, T, di, N) scan inputs d_inner-sharded over `model`
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.utils.sharding import axis_size, batch_axes
        ba = batch_axes(mesh)
        b_ax = ba if bsz % axis_size(mesh, ba) == 0 else None
        spec = NamedSharding(mesh, P(b_ax, None, "model", None))
        decay = jax.lax.with_sharding_constraint(decay, spec)
        drive = jax.lax.with_sharding_constraint(drive, spec)
    hs, hT = _scan_chunked(decay, drive, h0, chunk)
    y = jnp.einsum("btdn,btn->btd", hs, cc.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) * xif
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = dense(tg, f"{name}.out_proj", p["out_proj"], y)
    return out, {"conv": new_conv, "ssm": hT.astype(jnp.float32)}
