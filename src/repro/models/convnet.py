"""Small conv classifier — the KFC experimental family (1602.01407 §5).

Strided KFC-tagged convolutions (no pooling: every parameter sits inside a
Kronecker block), global average pool, one dense softmax head.  Serves the
``conv_classifier`` config as the tier-1 conv analogue of the paper's deep
autoencoder: small enough for CPU golden runs, but exercising the full
``ConvKronecker`` path (patch statistics, homogeneous bias, every
``inv_mode``) end to end through the real ``KFAC`` + ``Trainer`` loop.

Same model contract as :class:`repro.models.mlp.MLP`: ``metas``, ``loss``
(returning ``((loss_true, loss_sampled), aux)``), ``probe_shapes`` /
``make_probes`` and ``logits`` for the exact-Fisher quadratic
(``family="categorical"``).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.conv_classifier import ConvClassifierConfig
from repro.core.tags import LayerMeta, Tagger
from repro.models import params as PM
from repro.models.conv import conv, conv_meta, conv_out_len


class ConvNet:
    """KFC-tagged CNN classifier.  Input x: (B, H, W, C) images."""

    def __init__(self, cfg: ConvClassifierConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.nonlin = {"tanh": jnp.tanh, "relu": jax.nn.relu}[cfg.nonlin]
        self.defs: Dict[str, PM.ParamDef] = {}
        self.metas: Dict[str, LayerMeta] = {}
        c_in = cfg.channels
        self._stages = []
        for i, (c_out, k, stride) in enumerate(cfg.conv):
            name = f"conv{i}"
            self.defs[name] = PM.ParamDef((k * k * c_in + 1, c_out), P(),
                                          init="normal")
            self.metas[name] = conv_meta(
                name, (name,), spatial=(k, k), stride=(stride, stride),
                c_in=c_in, d_out=c_out, padding="SAME", bias=True)
            self._stages.append((name, c_in, (k, k), (stride, stride)))
            c_in = c_out
        self.defs["head"] = PM.ParamDef((c_in + 1, cfg.n_classes), P(),
                                        init="normal")
        self.metas["head"] = LayerMeta(
            name="head", param_path=("head",), d_in=c_in,
            d_out=cfg.n_classes, kind="dense", has_bias=True)
        self.contract_map = {}
        self.gcontract_map = {}           # fused_stats G-side hooks (core/fused)

    # -- params ---------------------------------------------------------
    def init_params(self, key):
        params = PM.materialize(key, self.defs)
        # materialize draws the full matrix; zero the homogeneous bias rows
        return {k: v.at[-1].set(0.0) for k, v in params.items()}

    def abstract_params(self, dtype=jnp.float32):
        return PM.abstract(self.defs, dtype, self.mesh)

    def n_params(self):
        return PM.count(self.defs)

    # -- forward --------------------------------------------------------
    def logits(self, params, x, tg: Optional[Tagger] = None):
        tg = tg or Tagger("plain")
        h = x
        side = self.cfg.image_size
        for name, c_in, spatial, stride in self._stages:
            b = h.shape[0]
            s = conv(tg, name, params[name], h.reshape(b, side, side, c_in),
                     spatial=spatial, stride=stride, padding="SAME")
            side = conv_out_len(side, spatial[0], stride[0], "SAME")
            h = self.nonlin(s)                      # (B, side², c_out)
        h = jnp.mean(h, axis=1)                     # global average pool
        hb = jnp.concatenate([h, jnp.ones((h.shape[0], 1), h.dtype)], -1)
        z = hb @ params["head"]
        return tg.tag("head", hb, z)

    def loss(self, params, probes, batch, rng, mode: str = "plain"):
        """((loss_true, loss_sampled), aux) — same contract as MLP/LM."""
        tg = Tagger(mode, probes, self.contract_map, self.gcontract_map)
        z = self.logits(params, batch["x"], tg)
        logp = jax.nn.log_softmax(z, axis=-1)
        lt = -jnp.mean(jnp.take_along_axis(
            logp, batch["y"][:, None], axis=-1))
        ys = jax.random.categorical(rng, jax.lax.stop_gradient(z), axis=-1)
        ls = -jnp.mean(jnp.take_along_axis(logp, ys[:, None], axis=-1))
        acc = jnp.mean((jnp.argmax(z, -1) == batch["y"]).astype(jnp.float32))
        return (lt, ls), {"recs": tg.out(),
                          "metrics": {"loss": lt, "accuracy": acc}}

    # -- probes ---------------------------------------------------------
    def probe_shapes(self, batch):
        def f(p, b):
            (lt, ls), aux = self.loss(p, None, b, jax.random.PRNGKey(0),
                                      mode="shapes")
            return aux["recs"]
        return jax.eval_shape(f, PM.abstract(self.defs), batch)

    def make_probes(self, shapes):
        return {k: jnp.zeros(v.shape, jnp.float32) for k, v in shapes.items()}
