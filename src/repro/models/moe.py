"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

Tokens are grouped by batch row (G=B, S=T); dispatch/combine tensors
``(B, S, E, C)`` shard over (data, model) and GSPMD lowers the dispatch
einsums to all-to-alls when experts are model-sharded.

K-FAC: the router is a standard dense tag; expert weights get **per-expert**
factors over the tokens routed to them (`kind="expert"`), with the dispatch
slot-validity mask as the per-position weight.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.tags import Tagger
from repro.models.layers import dense


def capacity(seq: int, n_experts: int, top_k: int, factor: float = 1.25) -> int:
    return max(1, int(math.ceil(seq * top_k / n_experts * factor)))


def _dispatch(gates, top_k: int, cap: int):
    """gates: (B, S, E) softmax router probs (non-diff ok).

    Returns the 0/1 dispatch tensor D: (B, S, E, C).
    """
    b, s, e = gates.shape
    _, topi = jax.lax.top_k(gates, top_k)                      # (B,S,k)
    counts = jnp.zeros((b, e), jnp.int32)
    d_parts = []
    for j in range(top_k):
        oh = jax.nn.one_hot(topi[..., j], e, dtype=jnp.int32)  # (B,S,E)
        pos_in_e = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        pos = jnp.sum(pos_in_e * oh, axis=-1)                  # (B,S)
        valid = (pos < cap)
        counts = counts + jnp.sum(oh, axis=1)
        dj = (oh.astype(jnp.float32)[..., None]
              * jax.nn.one_hot(pos, cap, dtype=jnp.float32)[..., None, :]
              * valid[..., None, None].astype(jnp.float32))    # (B,S,E,C)
        d_parts.append(dj)
    return sum(d_parts)


def moe_ffn(tg: Tagger, name: str, p: Dict, x, *, n_experts: int,
            top_k: int, cap_factor: float = 1.25):
    """x: (B, T, d).  p: router (d,E), gate/up (E,d,f), down (E,f,d)."""
    b, t, d = x.shape
    cap = capacity(t, n_experts, top_k, cap_factor)

    router_logits = dense(tg, f"{name}.router", p["router"], x)   # (B,T,E)
    gates = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    dsp = _dispatch(jax.lax.stop_gradient(gates), top_k, cap)
    # switch-style load-balance aux loss (token fraction x differentiable P_e)
    frac = jnp.mean(dsp.sum(-1), axis=1)                          # (B,E)
    aux = n_experts * jnp.mean(jnp.sum(frac * jnp.mean(gates, axis=1), axis=-1))
    # combine weights: dispatch mask x differentiable gate probs, renormalized
    comb = dsp * gates[..., None]
    comb = comb / jnp.maximum(comb.sum(axis=(-2, -1), keepdims=True), 1e-9)
    dsp = dsp.astype(x.dtype)
    comb = comb.astype(x.dtype)

    xe = jnp.einsum("bsec,bsd->becd", dsp, x)                     # dispatch
    slot_valid = jnp.einsum("bsec->bec", dsp)

    def etag(nm, a, s):
        return tg.tag(f"{name}.{nm}", a, s, weight=slot_valid)

    wg, wu, wd = p["gate"], p["up"], p["down"]
    hg = etag("gate", xe, jnp.einsum("becd,edf->becf", xe, wg.astype(x.dtype)))
    hu = etag("up", xe, jnp.einsum("becd,edf->becf", xe, wu.astype(x.dtype)))
    hh = jax.nn.silu(hg) * hu
    ye = etag("down", hh, jnp.einsum("becf,efd->becd", hh, wd.astype(x.dtype)))
    y = jnp.einsum("bsec,becd->bsd", comb, ye)                    # combine
    return y, aux
