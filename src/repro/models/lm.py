"""Unified LM builder: one code path for all 10 assigned architectures.

Layers are organized as a repeating *pattern* of block positions (period =
lcm of the arch's alternation features: local/global attention, MoE
interleave, Mamba:attention ratio).  Parameters for each pattern position are
stacked over ``n_groups = n_layers / period`` and the forward is a
``lax.scan`` over groups — fast compiles, and K-FAC factors come out
naturally stacked (vmapped inverses).

Three execution paths share the block code:
  * train/eval forward  (optionally K-FAC-tagged, builds no cache)
  * prefill             (plain forward that also emits the decode cache)
  * decode_step         (one token against a full cache)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, KFACConfig
from repro.core import factors as F
from repro.kernels import ops
from repro.core.tags import LayerMeta, Tagger, merge_records
from repro.models import params as PM
from repro.models.conv import conv, conv_meta
from repro.models.head import head_logits, lm_head_loss
from repro.models.layers import attention, apply_rope, dense, rms_norm
from repro.models.moe import moe_ffn
from repro.models.rwkv import rwkv_channel_mix, rwkv_time_mix
from repro.models.ssm import dt_rank, mamba_block
from repro.utils.sharding import axis_size, batch_axes, constrain, pick_shard

AUX_LOSS_WEIGHT = 0.01


@dataclass(frozen=True)
class BlockSpec:
    pos: int
    attn: str            # global | local | mamba | rwkv
    mlp: str             # dense | moe | rwkv_cm
    cross: bool = False  # enc-dec decoder cross-attention


def build_pattern(cfg: ModelConfig) -> List[BlockSpec]:
    if cfg.attn_free:
        return [BlockSpec(0, "rwkv", "rwkv_cm")]
    period = 1
    if cfg.alt_local_global:
        period = 2
    if cfg.n_experts and cfg.moe_every > 1:
        period = math.lcm(period, cfg.moe_every)
    if cfg.attn_every > 1:
        period = math.lcm(period, cfg.attn_every)
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    out = []
    for i in range(period):
        if cfg.attn_every > 1:
            attn = "global" if cfg.is_attn_layer(i) else "mamba"
        elif cfg.alt_local_global:
            attn = "local" if i % 2 == 0 else "global"
        else:
            attn = "global"
        mlp = "moe" if cfg.is_moe_layer(i) else "dense"
        out.append(BlockSpec(i, attn, mlp, cross=cfg.encoder_layers > 0))
    return out


def sinusoid_posemb(t: int, d: int, offset=0):
    pos = jnp.arange(t, dtype=jnp.float32) + offset
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class LM:
    def __init__(self, cfg: ModelConfig, kfac: Optional[KFACConfig] = None,
                 mesh=None, compute_dtype=jnp.float32, fsdp: bool = True):
        self.cfg = cfg
        self.kfac = kfac or KFACConfig()
        self.mesh = mesh
        self.cdtype = compute_dtype
        self.fsdp = fsdp
        self.pattern = build_pattern(cfg)
        self.period = len(self.pattern)
        self.n_groups = cfg.n_layers // self.period
        self.defs = self._param_defs()
        self.metas = self._layer_metas()
        self.contract_map = self._contract_map()
        self.gcontract_map = {}   # fused_stats G-side hooks (core/fused)

    # ------------------------------------------------------------------
    # parameter definitions
    # ------------------------------------------------------------------
    def _fs(self, dim):
        from repro.utils.sharding import pick_shard as _ps
        return _ps(dim, self.mesh, "data") if self.fsdp else None

    def _pd(self, shape, axes, lead=(), **kw):
        spec = P(*((None,) * len(lead)), *axes)
        return PM.ParamDef(shape=tuple(lead) + tuple(shape), spec=spec, **kw)

    def _block_defs(self, spec: BlockSpec, lead):
        cfg, m = self.cfg, self.mesh
        d, f = cfg.d_model, cfg.d_ff
        qd, kvd = cfg.q_dim, cfg.kv_dim
        fs = self._fs(d)                   # fsdp axis for d_model dims
        tp_q = pick_shard(qd, m, "model")
        tp_kv = pick_shard(kvd, m, "model")
        tp_f = pick_shard(f, m, "model")
        p: Dict[str, Any] = {"ln1": self._pd((d,), (None,), lead, init="zeros")}
        if spec.attn in ("global", "local"):
            p["attn"] = {
                "wq": self._pd((d, qd), (fs, tp_q), lead),
                "wk": self._pd((d, kvd), (fs, tp_kv), lead),
                "wv": self._pd((d, kvd), (fs, tp_kv), lead),
                "wo": self._pd((qd, d), (tp_q, fs), lead),
            }
        elif spec.attn == "mamba":
            di = cfg.ssm_expand * d
            r = dt_rank(d)
            n = cfg.ssm_state_dim
            tp_di = pick_shard(di, m, "model")
            p["mamba"] = {
                "in_proj": self._pd((d, 2 * di), (fs, tp_di), lead),
                "conv_w": self._pd((cfg.ssm_conv_dim, di), (None, tp_di), lead,
                                   init="normal", scale=0.5),
                "x_proj": self._pd((di, r + 2 * n), (tp_di, None), lead),
                "dt_proj": self._pd((r, di), (None, tp_di), lead),
                "dt_bias": self._pd((di,), (tp_di,), lead, init="zeros"),
                "A_log": self._pd((di, n), (tp_di, None), lead, init="zeros"),
                "D": self._pd((di,), (tp_di,), lead, init="ones"),
                "out_proj": self._pd((di, d), (tp_di, fs), lead),
            }
        elif spec.attn == "rwkv":
            hd = cfg.rwkv_head_dim
            h = d // hd
            tp_d = pick_shard(d, m, "model")
            lora = 64 if d >= 64 else 16
            p["ln2"] = self._pd((d,), (None,), lead, init="zeros")
            vec = lambda init="normal": self._pd((d,), (None,), lead, init=init,
                                                 scale=0.02)
            p["rwkv"] = {
                "mu_r": vec(), "mu_k": vec(), "mu_v": vec(), "mu_g": vec(),
                "mu_w": vec(), "mu_cr": vec(), "mu_ck": vec(),
                "wr": self._pd((d, d), (fs, tp_d), lead),
                "wk": self._pd((d, d), (fs, tp_d), lead),
                "wv": self._pd((d, d), (fs, tp_d), lead),
                "wg": self._pd((d, d), (fs, tp_d), lead),
                "wo": self._pd((d, d), (tp_d, fs), lead),
                "w_lora_a": self._pd((d, lora), (fs, None), lead),
                "w_lora_b": self._pd((lora, d), (None, tp_d), lead,
                                     init="zeros"),
                "w0": self._pd((d,), (tp_d,), lead, init="ones"),
                "u": self._pd((d,), (tp_d,), lead, init="zeros"),
                "ln_x": self._pd((h, hd), (None, None), lead, init="zeros"),
                "cm_wr": self._pd((d, d), (fs, tp_d), lead),
                "cm_wk": self._pd((d, f), (fs, tp_f), lead),
                "cm_wv": self._pd((f, d), (tp_f, fs), lead),
            }
        if spec.cross:
            p["ln_cross"] = self._pd((d,), (None,), lead, init="zeros")
            p["cross"] = {
                "wq": self._pd((d, qd), (fs, tp_q), lead),
                "wk": self._pd((d, kvd), (fs, tp_kv), lead),
                "wv": self._pd((d, kvd), (fs, tp_kv), lead),
                "wo": self._pd((qd, d), (tp_q, fs), lead),
            }
        if spec.mlp == "dense":
            p["ln2"] = self._pd((d,), (None,), lead, init="zeros")
            p["mlp"] = {
                "wg": self._pd((d, f), (fs, tp_f), lead),
                "wu": self._pd((d, f), (fs, tp_f), lead),
                "wd": self._pd((f, d), (tp_f, fs), lead),
            }
        elif spec.mlp == "moe":
            e = cfg.n_experts
            ep = pick_shard(e, m, "model")
            p["ln2"] = self._pd((d,), (None,), lead, init="zeros")
            p["moe"] = {
                "router": self._pd((d, e), (fs, None), lead),
                "gate": self._pd((e, d, f), (ep, fs, None), lead),
                "up": self._pd((e, d, f), (ep, fs, None), lead),
                "down": self._pd((e, f, d), (ep, None, fs), lead),
            }
            if cfg.moe_shared_expert:
                p["moe_shared"] = {
                    "wg": self._pd((d, f), (fs, tp_f), lead),
                    "wu": self._pd((d, f), (fs, tp_f), lead),
                    "wd": self._pd((f, d), (tp_f, fs), lead),
                }
        # rwkv_cm handled inside the rwkv dict
        return p

    def _param_defs(self):
        cfg, m = self.cfg, self.mesh
        d, v = cfg.d_model, cfg.vocab_size
        lead = (self.n_groups,)
        defs: Dict[str, Any] = {
            "embed": self._pd((v, d), (pick_shard(v, m, "model"),
                                       self._fs(d)), init="embed"),
            "final_ln": self._pd((d,), (None,), init="zeros"),
            "blocks": tuple(self._block_defs(s, lead) for s in self.pattern),
        }
        if not cfg.tie_embeddings:
            defs["head"] = self._pd((d, v), (self._fs(d),
                                             pick_shard(v, m, "model")))
        if cfg.encoder_layers:
            defs["enc_blocks"] = self._enc_block_defs((cfg.encoder_layers,))
            defs["enc_final_ln"] = self._pd((d,), (None,), init="zeros")
        if cfg.frontend == "audio":
            # whisper Conv1D stem: mels -> d (k=3 s=1), d -> d (k=3 s=2);
            # weights stored as tap-major patch matrices, bias = last row
            defs["enc_conv1"] = self._pd((3 * cfg.n_mels + 1, d),
                                         (None, self._fs(d)))
            defs["enc_conv2"] = self._pd((3 * d + 1, d),
                                         (None, self._fs(d)))
        if cfg.frontend == "patch":
            p, ic = cfg.patch_size, cfg.image_channels
            defs["vis_patch"] = self._pd((p * p * ic + 1, d),
                                         (None, self._fs(d)))
        return defs

    def _enc_block_defs(self, lead):
        cfg, m = self.cfg, self.mesh
        d, f, qd, kvd = cfg.d_model, cfg.d_ff, cfg.q_dim, cfg.kv_dim
        fs = self._fs(d)
        return {
            "ln1": self._pd((d,), (None,), lead, init="zeros"),
            "attn": {
                "wq": self._pd((d, qd), (fs, pick_shard(qd, m, "model")), lead),
                "wk": self._pd((d, kvd), (fs, pick_shard(kvd, m, "model")), lead),
                "wv": self._pd((d, kvd), (fs, pick_shard(kvd, m, "model")), lead),
                "wo": self._pd((qd, d), (pick_shard(qd, m, "model"), fs), lead),
            },
            "ln2": self._pd((d,), (None,), init="zeros", lead=lead),
            "mlp": {
                "wg": self._pd((d, f), (fs, pick_shard(f, m, "model")), lead),
                "wu": self._pd((d, f), (fs, pick_shard(f, m, "model")), lead),
                "wd": self._pd((f, d), (pick_shard(f, m, "model"), fs), lead),
            },
        }

    # ------------------------------------------------------------------
    # K-FAC layer metadata
    # ------------------------------------------------------------------
    def _dense_meta(self, name, path, pdef: PM.ParamDef, n_stack, n_expert=0,
                    probe_tshard=False):
        kf = self.kfac
        tp = 1 if self.mesh is None else int(self.mesh.shape.get("model", 1))
        # feature axes are the last two spec entries / shape dims
        d_in, d_out = pdef.shape[-2], pdef.shape[-1]
        sp = pdef.spec
        in_ax, out_ax = sp[-2] if len(sp) >= 2 else None, sp[-1] if len(sp) >= 1 else None
        a_kind, a_blocks = F.factor_layout(d_in, in_ax == "model", tp,
                                           kf.max_factor_dim)
        g_kind, g_blocks = F.factor_layout(d_out, out_ax == "model", tp,
                                           kf.max_factor_dim)
        return LayerMeta(name=name, param_path=path, d_in=d_in, d_out=d_out,
                         kind="expert" if n_expert else "dense",
                         n_stack=n_stack, n_expert=n_expert,
                         a_kind=a_kind, g_kind=g_kind,
                         a_blocks=a_blocks, g_blocks=g_blocks,
                         probe_tshard=probe_tshard)

    def _layer_metas(self) -> Dict[str, LayerMeta]:
        cfg = self.cfg
        ng = self.n_groups
        metas: Dict[str, LayerMeta] = {}

        def add(name, path, n_expert=0, n_stack=ng, probe_tshard=False):
            pdef = self.defs
            for k in path:
                pdef = pdef[k]
            metas[name] = self._dense_meta(name, path, pdef, n_stack, n_expert,
                                           probe_tshard)

        for pos, spec in enumerate(self.pattern):
            b = f"blk{pos}"
            bp = ("blocks", pos)
            if spec.attn in ("global", "local"):
                for w in ("q", "k", "v", "o"):
                    # context-parallel attention: q/k/v outputs live
                    # sequence-sharded, so their probes follow suit
                    add(f"{b}.attn.{w}", bp + ("attn", f"w{w}"),
                        probe_tshard=w in ("q", "k", "v"))
            elif spec.attn == "mamba":
                for w in ("in_proj", "x_proj", "dt_proj", "out_proj"):
                    add(f"{b}.mamba.{w}", bp + ("mamba", w))
            elif spec.attn == "rwkv":
                for w in ("r", "k", "v", "g", "o", "w_lora_a", "w_lora_b"):
                    key = {"r": "wr", "k": "wk", "v": "wv", "g": "wg",
                           "o": "wo"}.get(w, w)
                    add(f"{b}.rwkv.{w}", bp + ("rwkv", key))
                for w, key in (("cm_r", "cm_wr"), ("cm_k", "cm_wk"),
                               ("cm_v", "cm_wv")):
                    add(f"{b}.rwkv.{w}", bp + ("rwkv", key))
            if spec.cross:
                for w in ("q", "k", "v", "o"):
                    add(f"{b}.cross.{w}", bp + ("cross", f"w{w}"))
            if spec.mlp == "dense":
                for w, key in (("gate", "wg"), ("up", "wu"), ("down", "wd")):
                    add(f"{b}.mlp.{w}", bp + ("mlp", key))
            elif spec.mlp == "moe":
                add(f"{b}.moe.router", bp + ("moe", "router"))
                for w in ("gate", "up", "down"):
                    add(f"{b}.moe.{w}", bp + ("moe", w), n_expert=cfg.n_experts)
                if cfg.moe_shared_expert:
                    for w, key in (("gate", "wg"), ("up", "wu"), ("down", "wd")):
                        add(f"{b}.moe_shared.{w}", bp + ("moe_shared", key))
        if cfg.encoder_layers:
            for w in ("q", "k", "v", "o"):
                add(f"enc.attn.{w}", ("enc_blocks", "attn", f"w{w}"),
                    n_stack=cfg.encoder_layers)
            for w, key in (("gate", "wg"), ("up", "wu"), ("down", "wd")):
                add(f"enc.mlp.{w}", ("enc_blocks", "mlp", key),
                    n_stack=cfg.encoder_layers)
        # modality frontends: KFC conv blocks (Grosse & Martens 1602.01407)
        if cfg.frontend == "audio":
            metas["enc.conv1"] = conv_meta(
                "enc.conv1", ("enc_conv1",), spatial=(3,), stride=(1,),
                c_in=cfg.n_mels, d_out=cfg.d_model, padding="SAME",
                max_factor_dim=self.kfac.max_factor_dim)
            metas["enc.conv2"] = conv_meta(
                "enc.conv2", ("enc_conv2",), spatial=(3,), stride=(2,),
                c_in=cfg.d_model, d_out=cfg.d_model, padding="SAME",
                max_factor_dim=self.kfac.max_factor_dim)
        if cfg.frontend == "patch":
            metas["vis.patch"] = conv_meta(
                "vis.patch", ("vis_patch",), spatial=(cfg.patch_size,) * 2,
                stride=(cfg.patch_size,) * 2, c_in=cfg.image_channels,
                d_out=cfg.d_model, padding="VALID",
                max_factor_dim=self.kfac.max_factor_dim)
        # embedding: diagonal A (token frequencies), full G on d_model
        metas["embed"] = LayerMeta(
            name="embed", param_path=("embed",), d_in=cfg.vocab_size,
            d_out=cfg.d_model, kind="embed", n_stack=0,
            a_kind="diag", g_kind="full")
        if not cfg.tie_embeddings:
            metas["lm_head"] = LayerMeta(
                name="lm_head", param_path=("head",), d_in=cfg.d_model,
                d_out=cfg.vocab_size, kind="head", n_stack=0,
                a_kind="full", g_kind="diag")
        return metas

    def _contract_map(self):
        cm = {}
        for name, meta in self.metas.items():
            if meta.kind in ("dense", "expert", "head"):
                cm[name] = partial(F.outer_sum, kind=meta.a_kind,
                                   blocks=meta.a_blocks,
                                   expert=meta.kind == "expert")
        return cm

    # ------------------------------------------------------------------
    # initialization / abstraction
    # ------------------------------------------------------------------
    def init_params(self, key, dtype=jnp.float32):
        params = PM.materialize(key, self.defs, dtype)
        # conv stems: zero the homogeneous bias rows (MLP/ConvNet convention;
        # materialize draws the full matrix like a weight)
        for name in ("enc_conv1", "enc_conv2", "vis_patch"):
            if name in params:
                params[name] = params[name].at[-1].set(0.0)
        return params

    def abstract_params(self, dtype=jnp.float32):
        return PM.abstract(self.defs, dtype, self.mesh)

    def param_shardings(self):
        return PM.shardings(self.defs, self.mesh)

    def n_params(self) -> int:
        return PM.count(self.defs)

    # ------------------------------------------------------------------
    # block application (shared by train / prefill / decode)
    # ------------------------------------------------------------------
    def _attn(self, tg, name, p, x, positions, *, window, cache=None,
              decode_pos=None, build_cache=False, causal=True, kv_x=None,
              page_table=None):
        cfg = self.cfg
        bsz, t, _ = x.shape
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = dense(tg, f"{name}.q", p["wq"], x).reshape(bsz, t, hq, hd)
        xk = x if kv_x is None else kv_x
        tk = xk.shape[1]
        k = dense(tg, f"{name}.k", p["wk"], xk).reshape(bsz, tk, hkv, hd)
        v = dense(tg, f"{name}.v", p["wv"], xk).reshape(bsz, tk, hkv, hd)
        # context-parallel attention (train/prefill): queries stay
        # sequence-sharded over `model` (head counts need not divide the
        # mesh); the small GQA K/V are gathered across it.  The attention is
        # then a single unscanned block so GSPMD slices the score tensor
        # along the sharded T_q dim (a q-chunk scan would sequentialize).
        # Constraints sit on the bf16 projections, *before* the f32 RoPE
        # internals, so the collectives move bf16.
        cp = (cache is None and self.mesh is not None
              and pick_shard(t, self.mesh, "model") is not None
              and bsz % axis_size(self.mesh, batch_axes(self.mesh)) == 0)
        q_chunk = t if cp else None
        if cp:
            ba = batch_axes(self.mesh)
            q = constrain(q, self.mesh, P(ba, "model", None, None))
            k = constrain(k, self.mesh, P(ba, None, None, None))
            v = constrain(v, self.mesh, P(ba, None, None, None))
        use_rope = cfg.family not in ("audio",) and kv_x is None
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            kpos = positions if decode_pos is None else positions
            k = apply_rope(k, kpos, cfg.rope_theta)
        new_cache = None
        kv_valid = None
        q_offset = None
        if cache is not None and page_table is not None:
            # block-indexed paged decode: scatter this token's K/V straight
            # into its physical page (page = table[b, pos//P], offset =
            # pos%P; idle rows land on the allocator's null page), then
            # attend the page pool in place through the page table — the
            # dense (B, S_view) gather view is never materialized.
            assert t == 1, "paged decode is one token per row"
            page_size = cache["k"].shape[1]
            page = jnp.take_along_axis(
                page_table, (decode_pos // page_size)[:, None], axis=1)[:, 0]
            off = decode_pos % page_size
            ck = cache["k"].at[page, off].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[page, off].set(v[:, 0].astype(cache["v"].dtype))
            o = ops.flash_decode_paged(q[:, 0], ck, cv, decode_pos + 1,
                                       page_table, window=window,
                                       cap=cfg.attn_softcap)
            o = o[:, None].astype(x.dtype)
            o = dense(tg, f"{name}.o", p["wo"], o.reshape(bsz, t, hq * hd))
            return o, {"k": ck, "v": cv}
        if cache is not None:          # decode: splice into cache, per row
            # decode_pos is a (B,) vector — continuous-batching slots sit at
            # *different* positions, so each row splices at its own offset
            bidx = jnp.arange(bsz)
            tidx = decode_pos[:, None] + jnp.arange(t)[None, :]
            ck = cache["k"].at[bidx[:, None], tidx].set(
                k.astype(cache["k"].dtype))
            cv = cache["v"].at[bidx[:, None], tidx].set(
                v.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            if t == 1:
                # serve path: one token per row against the full cache —
                # route through the flash-decode kernel (einsum fallback
                # masks per-row; Pallas gets the lengths via scalar
                # prefetch).  Row b attends exactly [0, decode_pos[b]].
                o = ops.flash_decode(
                    q[:, 0], ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
                    decode_pos + 1, window=window, cap=cfg.attn_softcap)
                o = o[:, None].astype(x.dtype)
                o = dense(tg, f"{name}.o", p["wo"], o.reshape(bsz, t, hq * hd))
                return o, new_cache
            k, v = ck, cv
            kv_valid = (jnp.arange(k.shape[1])[None, :]
                        <= decode_pos[:, None] + t - 1)
            q_offset = decode_pos
        elif build_cache and kv_x is None:
            new_cache = {"k": k.astype(self.cdtype), "v": v.astype(self.cdtype)}
        o = attention(q, k, v, causal=causal, window=window,
                      cap=cfg.attn_softcap, q_offset=q_offset,
                      kv_valid=kv_valid,
                      **({"q_chunk": q_chunk} if q_chunk else {}))
        o = dense(tg, f"{name}.o", p["wo"], o.reshape(bsz, t, hq * hd))
        return o, new_cache

    def _seq_shard(self, x):
        """Constrain a block output back to the T-sharded residual layout —
        GSPMD then emits a reduce-scatter instead of an all-reduce."""
        if self.mesh is None:
            return x
        ba = batch_axes(self.mesh)
        if (x.shape[0] % axis_size(self.mesh, ba)
                or x.shape[1] % axis_size(self.mesh, "model")):
            return x
        return constrain(x, self.mesh,
                         P(ba, "model", *((None,) * (x.ndim - 2))))

    def _full_t(self, x):
        """Constrain to full-T (batch-sharded only) — pins GSPMD's reshard
        point onto this bf16 tensor instead of some f32 internal."""
        if self.mesh is None:
            return x
        ba = batch_axes(self.mesh)
        if x.shape[0] % axis_size(self.mesh, ba):
            return x
        return constrain(x, self.mesh, P(ba, *((None,) * (x.ndim - 1))))

    def _mlp(self, tg, name, p, x):
        g = dense(tg, f"{name}.gate", p["wg"], x)
        u = dense(tg, f"{name}.up", p["wu"], x)
        return dense(tg, f"{name}.down", p["wd"], jax.nn.silu(g) * u)

    def _apply_block(self, spec: BlockSpec, p, tg: Tagger, h, positions,
                     enc_out=None, cache=None, decode_pos=None,
                     build_cache=False, page_table=None):
        cfg = self.cfg
        name = f"blk{spec.pos}"
        aux = jnp.float32(0.0)
        new_cache: Dict[str, Any] = {}
        eps = cfg.norm_eps

        if spec.attn == "rwkv":
            st_tm = None if cache is None else cache
            y, st = rwkv_time_mix(tg, f"{name}.rwkv", p["rwkv"],
                                  rms_norm(h, p["ln1"], eps), st_tm,
                                  head_dim=cfg.rwkv_head_dim)
            h = h + y
            y2, st2 = rwkv_channel_mix(tg, f"{name}.rwkv", p["rwkv"],
                                       rms_norm(h, p["ln2"], eps), st_tm)
            h = h + y2
            if cache is not None or build_cache:
                new_cache.update(st)
                new_cache.update(st2)
            return h, aux, new_cache

        if spec.attn == "mamba":
            y, st = mamba_block(tg, f"{name}.mamba", p["mamba"],
                                rms_norm(h, p["ln1"], eps),
                                cache if cache is not None else None,
                                ssm_state_dim=cfg.ssm_state_dim,
                                conv_dim=cfg.ssm_conv_dim, mesh=self.mesh)
            h = h + y
            if cache is not None or build_cache:
                new_cache.update(st)
        else:
            window = cfg.sliding_window if spec.attn == "local" else 0
            o, kvc = self._attn(tg, f"{name}.attn", p["attn"],
                                rms_norm(h, p["ln1"], eps), positions,
                                window=window,
                                cache=None if cache is None else
                                {"k": cache["k"], "v": cache["v"]},
                                decode_pos=decode_pos, build_cache=build_cache,
                                page_table=page_table)
            h = h + o
            if kvc is not None:
                new_cache.update(kvc)

        if spec.cross:
            o, xc = self._cross_attn(tg, f"{name}.cross", p["cross"],
                                     rms_norm(h, p["ln_cross"], eps),
                                     enc_out, cache)
            h = h + o
            if cache is not None:   # decode: carry the cross cache forward
                new_cache["xk"] = cache["xk"]
                new_cache["xv"] = cache["xv"]
            elif build_cache:
                new_cache.update(xc)

        if spec.mlp == "dense":
            h = h + self._mlp(tg, f"{name}.mlp", p["mlp"],
                              rms_norm(h, p["ln2"], eps))
        elif spec.mlp == "moe":
            x = rms_norm(h, p["ln2"], eps)
            y, a = moe_ffn(tg, f"{name}.moe", p["moe"], x,
                           n_experts=cfg.n_experts, top_k=cfg.top_k)
            if cfg.moe_shared_expert:
                y = y + self._mlp(tg, f"{name}.moe_shared", p["moe_shared"], x)
            h = h + y
            aux = aux + a
        return h, aux, new_cache

    def _cross_attn(self, tg, name, p, x, enc_out, cache):
        """Decoder cross-attention. At decode time k/v come from the cache."""
        cfg = self.cfg
        bsz, t, _ = x.shape
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = dense(tg, f"{name}.q", p["wq"], x).reshape(bsz, t, hq, hd)
        if cache is not None and "xk" in cache:
            k, v = cache["xk"], cache["xv"]
        else:
            tk = enc_out.shape[1]
            k = dense(tg, f"{name}.k", p["wk"], enc_out).reshape(bsz, tk, hkv, hd)
            v = dense(tg, f"{name}.v", p["wv"], enc_out).reshape(bsz, tk, hkv, hd)
        o = attention(q, k, v, causal=False)
        o = dense(tg, f"{name}.o", p["wo"], o.reshape(bsz, t, hq * hd))
        xk = {} if cache is not None else {"xk": k.astype(self.cdtype),
                                           "xv": v.astype(self.cdtype)}
        return o, xk

    # ------------------------------------------------------------------
    # encoder (whisper)
    # ------------------------------------------------------------------
    def _encoder(self, params, mels, tg: Tagger, tg_mode, probes):
        """Whisper encoder: Conv1D stem (k=3 s=1, then k=3 s=2, GELU after
        each — both KFC-tagged on the OUTER tagger) + full-attention stack.
        mels: (B, 2*encoder_seq, n_mels) raw log-mel frames."""
        cfg = self.cfg
        x = conv(tg, "enc.conv1", params["enc_conv1"],
                 mels.astype(self.cdtype), spatial=(3,), stride=(1,),
                 padding="SAME")
        x = jax.nn.gelu(x)
        x = conv(tg, "enc.conv2", params["enc_conv2"], x, spatial=(3,),
                 stride=(2,), padding="SAME")
        x = jax.nn.gelu(x)
        x = x + sinusoid_posemb(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        pr = {k: v for k, v in (probes or {}).items()
              if k.startswith("enc.") and ".conv" not in k}

        def body(h, xs):
            p, prs = xs
            tg = Tagger(tg_mode, prs, self.contract_map, self.gcontract_map)
            o, _ = self._attn(tg, "enc.attn", p["attn"],
                              rms_norm(h, p["ln1"], cfg.norm_eps),
                              jnp.arange(h.shape[1]), window=0, causal=False)
            h = h + o
            h = h + self._mlp(tg, "enc.mlp", p["mlp"],
                              rms_norm(h, p["ln2"], cfg.norm_eps))
            return h, tg.out()

        h, recs = jax.lax.scan(jax.checkpoint(body), x,
                               (params["enc_blocks"], pr))
        return rms_norm(h, params["enc_final_ln"], cfg.norm_eps), recs

    # ------------------------------------------------------------------
    # full forwards
    # ------------------------------------------------------------------
    def _embed(self, params, tokens, tg: Tagger):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.cdtype)
        return tg.tag_embed("embed", tokens, x)

    def _backbone(self, params, x, positions, tg_mode, probes, enc_out=None):
        pr = {k: v for k, v in (probes or {}).items() if k.startswith("blk")}

        ba = batch_axes(self.mesh)
        b_ok = (self.mesh is not None
                and x.shape[0] % axis_size(self.mesh, ba) == 0)
        # sequence parallelism: the residual stream (and hence the per-layer
        # remat buffers) is sharded over `model` along T; blocks all-gather /
        # reduce-scatter at their boundaries (Megatron-SP pattern via GSPMD)
        t_ok = (self.mesh is not None
                and x.shape[1] % axis_size(self.mesh, "model") == 0)
        sp = P(ba if b_ok else None, "model" if t_ok else None, None)

        def body(carry, xs):
            h, auxl = carry
            bp, prs = xs
            if b_ok or t_ok:
                h = constrain(h, self.mesh, sp)
            tg = Tagger(tg_mode, prs, self.contract_map, self.gcontract_map)
            for pos, spec in enumerate(self.pattern):
                h, a, _ = self._apply_block(spec, bp[pos], tg, h, positions,
                                            enc_out=enc_out)
                auxl = auxl + a
            return (h, auxl), tg.out()

        (h, auxl), recs = jax.lax.scan(jax.checkpoint(body),
                                       (x, jnp.float32(0.0)),
                                       (params["blocks"], pr))
        return h, auxl, recs

    def _prepare_inputs(self, params, batch, tg: Tagger, probes, tg_mode):
        """Embed tokens + modality frontends. Returns (x, positions, labels,
        mask, enc_out, extra_recs)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, t = tokens.shape
        x = self._embed(params, tokens, tg)
        labels = batch["labels"]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        enc_out = None
        extra = {}
        if cfg.frontend == "patch":
            # Conv2D patchifier (KFC-tagged): raw images -> patch embeddings
            p = conv(tg, "vis.patch", params["vis_patch"],
                     batch["images"].astype(self.cdtype),
                     spatial=(cfg.patch_size,) * 2,
                     stride=(cfg.patch_size,) * 2, padding="VALID")
            p = p + sinusoid_posemb(p.shape[1], cfg.d_model
                                    ).astype(p.dtype)[None]
            x = jnp.concatenate([p, x], axis=1)
            pfx = jnp.zeros((bsz, p.shape[1]), labels.dtype)
            labels = jnp.concatenate([pfx, labels], axis=1)
            mask = jnp.concatenate([jnp.zeros_like(pfx, jnp.float32), mask],
                                   axis=1)
        elif cfg.frontend == "audio":
            enc_out, enc_recs = self._encoder(params, batch["mels"], tg,
                                              tg_mode, probes)
            extra.update(enc_recs)
            x = x + sinusoid_posemb(t, cfg.d_model).astype(x.dtype)[None]
        positions = jnp.arange(x.shape[1])
        return x, positions, labels, mask, enc_out, extra

    def head_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def _cast_params(self, params):
        """One conversion at entry: everything downstream (activations,
        tangents, FSDP gathers) then lives in the compute dtype."""
        if self.cdtype == jnp.float32:
            return params
        return jax.tree.map(
            lambda x: x.astype(self.cdtype)
            if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
            else x, params)

    def loss(self, params, probes, batch, rng, mode: str = "plain"):
        """Returns ((loss_true, loss_sampled), aux)."""
        cfg = self.cfg
        params = self._cast_params(params)
        tg = Tagger(mode, probes, self.contract_map, self.gcontract_map)
        x, positions, labels, mask, enc_out, extra = self._prepare_inputs(
            params, batch, tg, probes, mode)
        h, auxl, recs = self._backbone(params, x, positions, mode, probes,
                                       enc_out)
        if self.mesh is not None:   # gather T for the (B, c)-tiled head
            ba = batch_axes(self.mesh)
            b_ok = x.shape[0] % axis_size(self.mesh, ba) == 0
            h = constrain(h, self.mesh, P(ba if b_ok else None, None, None))
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        lt, ls, metrics = lm_head_loss(
            tg, h, self.head_weight(params), labels, mask, rng,
            logit_cap=cfg.logit_softcap)
        loss_t = lt + AUX_LOSS_WEIGHT * auxl
        all_recs = merge_records(tg.out(), recs, extra)
        metrics["aux_loss"] = auxl
        return (loss_t, ls), {"recs": all_recs, "metrics": metrics}

    def loss_only(self, params, batch, rng):
        (lt, _), aux = self.loss(params, None, batch, rng, mode="plain")
        return lt, aux["metrics"]

    def hidden(self, params, batch):
        """Final normed hidden states (for exact-Fisher J-products, App C)."""
        params = self._cast_params(params)
        tg = Tagger("plain")
        x, positions, labels, mask, enc_out, _ = self._prepare_inputs(
            params, batch, tg, None, "plain")
        h, _, _ = self._backbone(params, x, positions, "plain", None, enc_out)
        if self.mesh is not None:
            ba = batch_axes(self.mesh)
            b_ok = x.shape[0] % axis_size(self.mesh, ba) == 0
            h = constrain(h, self.mesh, P(ba if b_ok else None, None, None))
        h = rms_norm(h, params["final_ln"], self.cfg.norm_eps)
        return h, labels, mask

    # ------------------------------------------------------------------
    # probes
    # ------------------------------------------------------------------
    def probe_shapes(self, batch_abs, params_abs=None):
        params_abs = params_abs or self.abstract_params()

        def f(p, b):
            (lt, ls), aux = self.loss(p, None, b, jax.random.PRNGKey(0),
                                      mode="shapes")
            return aux["recs"]

        return jax.eval_shape(f, params_abs, batch_abs)

    def _probe_spec(self, name: str, shape) -> P:
        """Sharding for a probe (and hence its g cotangent): batch over
        (pod, data), expert/model dims over model."""
        m = self.mesh
        meta = self.metas.get(name)
        axes = [None] * len(shape)
        i0 = 1 if (meta is not None and meta.n_stack) else 0
        ba = batch_axes(m)
        if m is not None and shape[i0] % axis_size(m, ba) == 0:
            axes[i0] = ba
        if meta is not None and meta.kind == "expert":
            axes[i0 + 1] = pick_shard(shape[i0 + 1], m, "model")
        elif meta is not None and meta.probe_tshard and len(shape) >= i0 + 3:
            # context-parallel outputs (attention q/k/v): sequence-sharded
            axes[-2] = pick_shard(shape[-2], m, "model")
        elif meta is not None and meta.g_kind == "block" and not (
                meta.probe_tshard):
            # model-shard the feature dim only when the G factor is blocked
            # along it (otherwise the full-G contraction would re-gather)
            axes[-1] = pick_shard(shape[-1], m, "model")
        elif len(shape) >= i0 + 3:
            # full-G layers: their outputs are model-replicated, so the
            # probe (and its cotangent) sequence-shards over model for free
            axes[-2] = pick_shard(shape[-2], m, "model")
        return P(*axes)

    def make_probes(self, shapes):
        out = {}
        for k, v in shapes.items():
            z = jnp.zeros(v.shape, self.cdtype)
            if self.mesh is not None:
                z = jax.lax.with_sharding_constraint(
                    z, jax.sharding.NamedSharding(self.mesh,
                                                  self._probe_spec(k, v.shape)))
            out[k] = z
        return out

    # ------------------------------------------------------------------
    # serving: prefill + decode
    # ------------------------------------------------------------------
    def prefill(self, params, batch, return_hidden: bool = False):
        """Full forward; returns (last-token logits, cache) — or
        (logits, cache, last-token hidden state) with ``return_hidden``
        (the post-final-norm ``(B, d_model)`` features the Laplace
        uncertainty head consumes; the default path is untouched so
        compiled serving graphs stay bitwise-identical)."""
        cfg = self.cfg
        params = self._cast_params(params)
        tg = Tagger("plain")
        x, positions, _, _, enc_out, _ = self._prepare_inputs(
            params, {"tokens": batch["tokens"],
                     "labels": jnp.zeros_like(batch["tokens"]),
                     **{k: v for k, v in batch.items()
                        if k in ("images", "mels")}}, tg, None, "plain")

        def body(h, bp):
            caches = {}
            for pos, spec in enumerate(self.pattern):
                h, _, c = self._apply_block(spec, bp[pos], tg, h, positions,
                                            enc_out=enc_out, build_cache=True)
                caches[f"pos{pos}"] = c
            return h, caches

        h, cache = jax.lax.scan(body, x, params["blocks"])
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = head_logits(h[:, -1:, :], self.head_weight(params),
                             cfg.logit_softcap)
        if enc_out is not None:
            cache["enc_out"] = enc_out
        if return_hidden:
            return logits, cache, h[:, -1, :]
        return logits, cache

    def decode_step(self, params, cache, tokens, pos, page_table=None,
                    return_hidden: bool = False):
        """One decode step. tokens: (B, 1); pos: scalar int32 position, or a
        ``(B,)`` vector of *per-slot* positions (continuous batching: each
        slot splices and attends at its own offset).

        With ``page_table`` (a ``(B, max_blocks)`` int32 block table) the
        cache leaves are *page pools* ``(ng, num_pages, page_size, hkv,
        hd)`` shared by all rows: each attention layer scatters its one new
        KV row into the slot's physical page and attends block-indexed
        through the table (``ops.flash_decode_paged``) — no dense per-row
        cache view is built.  Without it the leaves are the dense
        ``(ng, B, S, hkv, hd)`` caches, spliced and attended as before.

        ``return_hidden`` additionally returns the post-final-norm
        ``(B, d_model)`` hidden state (Laplace uncertainty input)."""
        cfg = self.cfg
        params = self._cast_params(params)
        tg = Tagger("plain")
        x = self._embed(params, tokens, tg)
        pos = jnp.asarray(pos, jnp.int32)
        pos_vec = jnp.broadcast_to(pos.reshape(-1), (tokens.shape[0],))
        if cfg.frontend == "audio":
            half = cfg.d_model // 2
            freq = jnp.exp(-math.log(10000.0)
                           * jnp.arange(half, dtype=jnp.float32) / half)
            ang = pos_vec.astype(jnp.float32)[:, None] * freq[None, :]
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
            x = x + pe[:, None, :].astype(x.dtype)
        positions = pos_vec[:, None]
        enc_out = cache.get("enc_out") if isinstance(cache, dict) else None

        def body(h, xs):
            bp, cs = xs
            new_cs = {}
            for pos_i, spec in enumerate(self.pattern):
                h, _, c = self._apply_block(spec, bp[pos_i], tg, h, positions,
                                            enc_out=enc_out,
                                            cache=cs[f"pos{pos_i}"],
                                            decode_pos=pos_vec,
                                            page_table=page_table)
                new_cs[f"pos{pos_i}"] = c
            return h, new_cs

        layer_cache = {k: v for k, v in cache.items() if k.startswith("pos")}
        h, new_cache = jax.lax.scan(body, x, (params["blocks"], layer_cache))
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = head_logits(h, self.head_weight(params), cfg.logit_softcap)
        if enc_out is not None:
            new_cache["enc_out"] = enc_out
        if return_hidden:
            return logits, new_cache, h[:, -1, :]
        return logits, new_cache

    # ------------------------------------------------------------------
    # cache construction (decode dry-run entry: a *full* cache of length S)
    # ------------------------------------------------------------------
    def cache_defs(self, batch_size: int, cache_len: int):
        """ParamDef tree for a decode cache (zeros init, proper shardings).

        Sharding: batch over (pod?, data) when it divides; otherwise the
        sequence dim is data-sharded (long-context decode with batch=1).
        """
        cfg, m = self.cfg, self.mesh
        ba = batch_axes(m) if m is not None else ("data",)
        bs_ok = m is not None and batch_size % axis_size(m, ba) == 0
        b_ax = ba if bs_ok else None
        # flash-decode layout: the cache sequence dim shards over `model`
        # (and over `data` too when the batch can't use it) — each shard
        # scores its local KV slice; softmax partials all-reduce tiny scalars
        s_axes = []
        if not bs_ok and pick_shard(cache_len, m, "data"):
            s_axes.append("data")
        if pick_shard(cache_len, m, "model"):
            s_axes.append("model")
        s_ax = tuple(s_axes) if s_axes else None
        hd_ax = None
        ng = self.n_groups

        def kv():
            return {
                "k": PM.ParamDef((ng, batch_size, cache_len, cfg.n_kv_heads,
                                  cfg.hd), P(None, b_ax, s_ax, None, hd_ax),
                                 init="zeros", dtype="bfloat16"),
                "v": PM.ParamDef((ng, batch_size, cache_len, cfg.n_kv_heads,
                                  cfg.hd), P(None, b_ax, s_ax, None, hd_ax),
                                 init="zeros", dtype="bfloat16"),
            }

        d = cfg.d_model
        di = cfg.ssm_expand * d
        tp_di = pick_shard(di, m, "model")
        tp_d = pick_shard(d, m, "model")
        caches = {}
        for pos, spec in enumerate(self.pattern):
            c = {}
            if spec.attn in ("global", "local"):
                c = kv()
            elif spec.attn == "mamba":
                c = {
                    "conv": PM.ParamDef((ng, batch_size, cfg.ssm_conv_dim - 1,
                                         di), P(None, b_ax, None, tp_di),
                                        init="zeros", dtype="bfloat16"),
                    "ssm": PM.ParamDef((ng, batch_size, di, cfg.ssm_state_dim),
                                       P(None, b_ax, tp_di, None),
                                       init="zeros"),
                }
            elif spec.attn == "rwkv":
                hd = cfg.rwkv_head_dim
                nh = d // hd
                c = {
                    "shift_tm": PM.ParamDef((ng, batch_size, d),
                                            P(None, b_ax, tp_d), init="zeros",
                                            dtype="bfloat16"),
                    "shift_cm": PM.ParamDef((ng, batch_size, d),
                                            P(None, b_ax, tp_d), init="zeros",
                                            dtype="bfloat16"),
                    "wkv": PM.ParamDef((ng, batch_size, nh, hd, hd),
                                       P(None, b_ax, None, None, None),
                                       init="zeros"),
                }
            if spec.cross:
                c["xk"] = PM.ParamDef((ng, batch_size, cfg.encoder_seq,
                                       cfg.n_kv_heads, cfg.hd),
                                      P(None, b_ax, None, None, hd_ax),
                                      init="zeros", dtype="bfloat16")
                c["xv"] = PM.ParamDef((ng, batch_size, cfg.encoder_seq,
                                       cfg.n_kv_heads, cfg.hd),
                                      P(None, b_ax, None, None, hd_ax),
                                      init="zeros", dtype="bfloat16")
            caches[f"pos{pos}"] = c
        if cfg.encoder_layers:
            caches["enc_out"] = PM.ParamDef(
                (batch_size, cfg.encoder_seq, d), P(b_ax, None, None),
                init="zeros", dtype="bfloat16")
        return caches
