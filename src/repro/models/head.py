"""Chunked LM head: CE loss + predictive-distribution sampling + head stats.

The logits for large-vocab models are never materialized in full: a
`lax.scan` over chunks of the *sequence axis* computes, per (B, c) tile,

* the true-label CE (the objective),
* a sampled label ``ŷ ~ softmax(logits)`` and its CE — the *model-distribution*
  loss whose backward pass yields the g statistics K-FAC needs (S5; never the
  empirical Fisher),
* the analytic head pre-activation gradient ``g = softmax − onehot(ŷ)`` whose
  squared sum gives the head's **diagonal** G factor (vocab-sized dims use
  diagonal factors, DESIGN §3).

Chunking over T (not flat tokens) keeps every chunk aligned with the batch
sharding — all data shards work on every chunk, no resharding.  Each chunk
body is rematerialized, so backward never stores logits either.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tags import Tagger
from repro.models.layers import softcap


def _pick_chunk(n: int, target: int) -> int:
    c = max(1, min(n, target))
    while n % c:
        c -= 1
    return c


def lm_head_loss(tg: Tagger, h, w_head, labels, mask, rng, *,
                 logit_cap: float = 0.0, name: str = "lm_head",
                 chunk_target: int = 128):
    """h: (B, T, d) final hidden; labels/mask: (B, T).

    Returns ``(loss_true, loss_samp, metrics)`` — losses normalized by the
    static token count B*T.  In collect mode, records the head's A-side
    contraction and diagonal-G statistic on the tagger.
    """
    b, t, d = h.shape
    v = w_head.shape[-1]
    n = b * t
    chunk = _pick_chunk(t, chunk_target)
    nc = t // chunk
    collect = tg.mode == "collect"

    keys = jax.random.split(rng, nc)
    xs = (h.reshape(b, nc, chunk, d).swapaxes(0, 1),
          labels.reshape(b, nc, chunk).swapaxes(0, 1),
          mask.astype(jnp.float32).reshape(b, nc, chunk).swapaxes(0, 1),
          keys)

    def body(carry, xs_c):
        loss_t, loss_s, gsq, aa = carry
        hc, yc, mc, key = xs_c                       # (B,c,d),(B,c),(B,c)
        logits = jnp.einsum("bcd,dv->bcv", hc, w_head.astype(hc.dtype))
        logits = softcap(logits.astype(jnp.float32), logit_cap)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce_t = -jnp.take_along_axis(logp, yc[..., None], axis=-1)[..., 0] * mc
        ys = jax.random.categorical(key, jax.lax.stop_gradient(logits),
                                    axis=-1)
        ce_s = -jnp.take_along_axis(logp, ys[..., None], axis=-1)[..., 0] * mc
        loss_t = loss_t + jnp.sum(ce_t)
        loss_s = loss_s + jnp.sum(ce_s)
        if collect:
            g = jax.lax.stop_gradient(
                (jnp.exp(logp) - jax.nn.one_hot(ys, v, dtype=jnp.float32))
                * mc[..., None])
            gsq = gsq + jnp.sum(g * g, axis=(0, 1))
            hsg = jax.lax.stop_gradient(hc)
            aa = aa + jnp.einsum("bcd,bce->de", hsg, hsg,
                                 preferred_element_type=jnp.float32)
        return (loss_t, loss_s, gsq, aa), None

    aa0 = jnp.zeros((d, d) if collect else (1, 1), jnp.float32)
    init = (jnp.float32(0.0), jnp.float32(0.0),
            jnp.zeros((v,) if collect else (1,), jnp.float32), aa0)
    (loss_t, loss_s, gsq, aa), _ = jax.lax.scan(jax.checkpoint(body), init, xs)

    if collect and name in tg.contract:
        # tied-embedding archs have no separate head block and skip this
        tg.records[name] = {"aa": aa, "gdiag": gsq / n}

    norm = 1.0 / n
    metrics = {"loss": loss_t * norm}
    return loss_t * norm, loss_s * norm, metrics


def head_logits(h, w_head, logit_cap: float = 0.0):
    """Unchunked logits for serving (decode steps have tiny N)."""
    logits = jnp.einsum("...d,dv->...v", h, w_head.astype(h.dtype))
    return softcap(logits.astype(jnp.float32), logit_cap)
