"""Convolution layers with KFC curvature tags (Grosse & Martens 1602.01407).

A convolution is treated as a dense map over im2col *patches*: each spatial
output location contributes one "token" whose features are the receptive
field flattened tap-major (``feature = k * C + c``), so the weight lives as
a ``(prod(K)*C [+1], d_out)`` matrix — the bias as a homogeneous last row,
exactly the MLP convention — and every K-FAC code path (factor layout,
damped inverses, eigen mode, the Pallas precondition kernels) applies
unchanged.  The forward *computes* the conv as ``patches @ W`` so the
weight-matrix gradient is ``Σ_t patch_t g_tᵀ`` by construction, consistent
with the ``ConvKronecker`` factor statistics.

The tap-major layout means ``W[:-1].reshape(*K, C, d_out)`` is a lax conv
kernel in ``WIO`` / ``HWIO`` form; :func:`extract_patches` transposes
``jax.lax.conv_general_dilated_patches`` (channel-major) into it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import factors as F
from repro.core.tags import LayerMeta, Tagger

_DIM_NUMS = {1: ("NWC", "WIO", "NWC"), 2: ("NHWC", "HWIO", "NHWC")}


def conv_out_len(t: int, k: int, stride: int, padding: str) -> int:
    """Spatial output length of one conv dim (lax "SAME"/"VALID" rules)."""
    if padding == "SAME":
        return -(-t // stride)
    return max(0, (t - k) // stride + 1)


def extract_patches(x, spatial: Tuple[int, ...], stride: Tuple[int, ...],
                    padding: str = "VALID"):
    """im2col in the repo's tap-major layout.

    x: ``(B, *S, C)`` -> ``(B, T_out, prod(K)*C)`` with feature index
    ``k * C + c`` (spatial tap major, input channel minor — the row order of
    the conv weight matrix).  ``jax.lax.conv_general_dilated_patches``
    returns the channel-major order ``c * prod(K) + k``; this transposes it.
    """
    nd = len(spatial)
    c = x.shape[-1]
    p = jax.lax.conv_general_dilated_patches(
        x, filter_shape=spatial, window_strides=stride, padding=padding,
        dimension_numbers=_DIM_NUMS[nd])
    b = x.shape[0]
    t = int(np.prod(p.shape[1:-1]))
    k = int(np.prod(spatial))
    p = p.reshape(b, t, c, k)
    return jnp.swapaxes(p, -1, -2).reshape(b, t, k * c)


def append_homog(p):
    """Homogeneous coordinate: ``â = [patch; 1]`` (bias = last weight row)."""
    return jnp.concatenate(
        [p, jnp.ones((*p.shape[:-1], 1), p.dtype)], axis=-1)


def conv(tg: Tagger, name: str, w, x, *, spatial: Tuple[int, ...],
         stride: Tuple[int, ...], padding: str = "VALID", bias: bool = True):
    """K-FAC-tagged convolution: ``s = patches(x) @ W [+ b]``.

    x: ``(B, *S, C)``; w: ``(prod(spatial)*C [+1], d_out)``.  Returns the
    outputs with spatial dims flattened, ``(B, T_out, d_out)`` — frontends
    consume them as a token sequence anyway.
    """
    p = extract_patches(x, spatial, stride, padding)
    wm = w.astype(x.dtype)
    s = p @ (wm[:-1] if bias else wm)
    if bias:
        s = s + wm[-1]
    return tg.tag_conv(name, x, s)


def conv_meta(name: str, path: Tuple, *, spatial: Tuple[int, ...],
              stride: Tuple[int, ...], c_in: int, d_out: int,
              padding: str = "VALID", bias: bool = True,
              max_factor_dim: int = 8_192) -> LayerMeta:
    """LayerMeta for one KFC conv block (kind="conv", tap-major weight)."""
    d_in = int(np.prod(spatial)) * c_in
    a_kind, a_blocks = F.factor_layout(d_in, False, 1, max_factor_dim)
    g_kind, g_blocks = F.factor_layout(d_out, False, 1, max_factor_dim)
    return LayerMeta(name=name, param_path=path, d_in=d_in, d_out=d_out,
                     kind="conv", a_kind=a_kind, g_kind=g_kind,
                     a_blocks=a_blocks, g_blocks=g_blocks, has_bias=bias,
                     conv_spatial=tuple(spatial), conv_stride=tuple(stride),
                     conv_in=c_in, conv_pad=padding)
