"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Training/prefill uses the chunked linear-attention formulation: within a
chunk, pairwise decay differences are applied in log-space (all exponents
<= 0, so numerically safe); across chunks, the (B, H, hd, hd) wkv state is
propagated.  Decode is the exact recurrence.

Recurrence per head (r, k, v: (hd,), w: (hd,) in (0,1), u: bonus):
    out_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

K-FAC: all projections (r/k/v/g/out, decay LoRA, channel-mix) are dense tags;
the per-channel decay base w0 / bonus u / mix vectors use the diagonal
Fisher fallback.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.tags import Tagger
from repro.models.layers import dense, rms_norm

RWKV_CHUNK = 32


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` for t=0). x: (B,T,d)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None, :]
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _chunked_wkv(r, k, v, logw, u, s0, chunk: int):
    """r,k,v: (B,T,H,hd); logw: (B,T,H,hd) (<=0); u: (H,hd); s0: (B,H,hd,hd).

    Returns (out: (B,T,H,hd), sT).  out_t[j] = sum_i r_t[i] * M_t[i,j].
    """
    bsz, t, h, hd = r.shape
    c = min(chunk, t)
    while t % c:
        c -= 1
    nc = t // c

    def resh(x):
        return x.reshape(bsz, nc, c, h, hd).swapaxes(0, 1)

    rs, ks, vs, lws = resh(r), resh(k), resh(v), resh(logw)

    def body(s, xs):
        rc, kc, vc, lwc = xs                    # (B,c,H,hd)
        cw = jnp.cumsum(lwc, axis=1)            # cumulative log-decay incl. t
        cw_prev = cw - lwc                      # decay up to t-1 (exclusive)
        # inter-chunk: r_t through decayed initial state
        r_dec = rc * jnp.exp(cw_prev)
        out = jnp.einsum("bchi,bhij->bchj", r_dec, s)
        # intra-chunk: pairwise decay exp(cw_prev[t] - cw[s]) for s < t
        diff = cw_prev[:, :, None] - cw[:, None, :]   # (B,c,c,H,hd): t,s
        tri = jnp.tril(jnp.ones((c, c), bool), -1)[None, :, :, None, None]
        att = jnp.where(tri, jnp.exp(diff), 0.0)
        scores = jnp.einsum("bthi,btshi,bshi->bths", rc, att, kc)
        out = out + jnp.einsum("bths,bshj->bthj", scores, vc)
        # bonus (current token) term
        bonus = jnp.einsum("bthi,hi,bthi->bth", rc, u, kc)
        out = out + bonus[..., None] * vc
        # state update: S' = diag(exp(cw_T)) S + sum_s exp(cw_T - cw[s]) k_s v_s^T
        cw_t = cw[:, -1]                        # (B,H,hd)
        k_dec = kc * jnp.exp(cw_t[:, None] - cw)
        s = jnp.exp(cw_t)[..., None] * s + jnp.einsum(
            "bshi,bshj->bhij", k_dec, vc)
        return s, out

    sT, outs = jax.lax.scan(body, s0, (rs, ks, vs, lws))
    return outs.swapaxes(0, 1).reshape(bsz, t, h, hd), sT


def rwkv_time_mix(tg: Tagger, name: str, p: Dict, x, state: Optional[Dict],
                  *, head_dim: int, chunk: int = RWKV_CHUNK):
    """x: (B,T,d). state: None or {"shift": (B,d), "wkv": (B,H,hd,hd)}."""
    bsz, t, d = x.shape
    h = d // head_dim
    xp = _shift(x, None if state is None else state["shift_tm"])

    def mix(mu):
        return x + (xp - x) * mu.astype(x.dtype)

    r = dense(tg, f"{name}.r", p["wr"], mix(p["mu_r"]))
    kk = dense(tg, f"{name}.k", p["wk"], mix(p["mu_k"]))
    v = dense(tg, f"{name}.v", p["wv"], mix(p["mu_v"]))
    g = dense(tg, f"{name}.g", p["wg"], mix(p["mu_g"]))
    # data-dependent decay (LoRA on the shifted-mix input)
    xw = mix(p["mu_w"])
    wlo = dense(tg, f"{name}.w_lora_a", p["w_lora_a"], xw)
    wlo = dense(tg, f"{name}.w_lora_b", p["w_lora_b"], jnp.tanh(wlo))
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + wlo.astype(jnp.float32))
    logw = jnp.clip(logw, -20.0, -1e-4)          # log of decay in (0,1)

    def heads(z):
        return z.reshape(bsz, t, h, head_dim)

    s0 = (jnp.zeros((bsz, h, head_dim, head_dim), jnp.float32)
          if state is None else state["wkv"])
    out, sT = _chunked_wkv(heads(r).astype(jnp.float32),
                           heads(kk).astype(jnp.float32),
                           heads(v).astype(jnp.float32),
                           heads(logw),
                           p["u"].astype(jnp.float32).reshape(h, head_dim),
                           s0, chunk)
    out = rms_norm(out, p["ln_x"], 1e-5)         # per-head group norm
    out = out.reshape(bsz, t, d).astype(x.dtype) * jax.nn.silu(g)
    y = dense(tg, f"{name}.o", p["wo"], out)
    new_state = {"shift_tm": x[:, -1, :], "wkv": sT}
    return y, new_state


def rwkv_channel_mix(tg: Tagger, name: str, p: Dict, x,
                     state: Optional[Dict]):
    xp = _shift(x, None if state is None else state["shift_cm"])

    def mix(mu):
        return x + (xp - x) * mu.astype(x.dtype)

    r = dense(tg, f"{name}.cm_r", p["cm_wr"], mix(p["mu_cr"]))
    k = dense(tg, f"{name}.cm_k", p["cm_wk"], mix(p["mu_ck"]))
    kk = jnp.square(jax.nn.relu(k))
    y = dense(tg, f"{name}.cm_v", p["cm_wv"], kk)
    out = jax.nn.sigmoid(r) * y
    return out, {"shift_cm": x[:, -1, :]}
