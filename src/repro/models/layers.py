"""Shared model layers: norm, RoPE, dense (K-FAC tagged), attention.

Attention is computed in query chunks with a plain per-chunk softmax (each
chunk sees the full key range), which bounds the score buffer to
``(B, H, chunk, Tk)`` — the pure-jnp analogue of the Pallas flash kernel in
``repro.kernels`` (which is used on real TPUs; this path is its oracle).
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.core.tags import Tagger

DEFAULT_Q_CHUNK = 256


def rms_norm(x, scale, eps: float):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def dense(tg: Tagger, name: str, w, x):
    """K-FAC-tagged linear map: s = x @ w (no bias; LLM convention)."""
    s = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    return tg.tag(name, x, s)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, T, H, hd); positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, T, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA + causal + sliding window + softcap), query-chunked
# ---------------------------------------------------------------------------

def _attn_chunk(q, k, v, q_pos, k_pos, *, causal, window, cap, kv_valid):
    """q: (B, Cq, Hq, hd); k/v: (B, Tk, Hkv, hd); k_pos is 1-d; q_pos is
    (Cq,) shared or (B, Cq) per-row (continuous-batching decode)."""
    b, cq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, cq, hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    scores = softcap(scores, cap)
    per_row = q_pos.ndim == 2
    dq = q_pos[..., :, None]             # (Cq,1) or (B,Cq,1)
    dk = k_pos[None, :] if not per_row else k_pos[None, None, :]
    mask = jnp.ones(dq.shape[:-1] + (tk,), dtype=bool)
    if causal:
        mask &= dq >= dk
    if window:
        mask &= dq - dk < window
    if kv_valid is not None:  # (B, Tk) validity for decode caches
        if not per_row:
            mask = mask[None]
        mask = mask & kv_valid[:, None, :]
        mask = mask[:, None, None]  # (B,1,1,Cq,Tk)
    elif per_row:
        mask = mask[:, None, None]
    else:
        mask = mask[None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, cq, hq, hd)


def attention(q, k, v, *, causal=True, window=0, cap=0.0, q_offset=None,
              kv_valid=None, q_chunk: int = DEFAULT_Q_CHUNK):
    """Multi-head attention with GQA.

    q: (B, Tq, Hq, hd);  k, v: (B, Tk, Hkv, hd).
    q_offset: position of q[0] (decode) — scalar, or (B,) per-row for
    continuous-batching slots at different positions; default 0
    (prefill/train aligned so q_pos = arange(Tq), k_pos = arange(Tk)).
    kv_valid: (B, Tk) bool — valid cache entries during decode.
    """
    b, tq, hq, hd = q.shape
    tk = k.shape[1]
    k_pos = jnp.arange(tk)
    if q_offset is None:
        q_pos0 = jnp.arange(tq)
    elif jnp.ndim(q_offset) == 0:
        q_pos0 = q_offset + jnp.arange(tq)
    else:                          # (B,) per-row offsets -> (B, Tq)
        q_pos0 = jnp.asarray(q_offset)[:, None] + jnp.arange(tq)[None, :]

    if tq <= q_chunk:
        return _attn_chunk(q, k, v, q_pos0, k_pos, causal=causal,
                           window=window, cap=cap, kv_valid=kv_valid)

    while tq % q_chunk:           # largest divisor of tq <= requested chunk
        q_chunk -= 1
    n = tq // q_chunk
    qs = q.reshape(b, n, q_chunk, hq, hd).swapaxes(0, 1)  # (n, B, Cq, Hq, hd)
    if q_pos0.ndim == 2:
        ps = q_pos0.reshape(b, n, q_chunk).swapaxes(0, 1)  # (n, B, Cq)
    else:
        ps = q_pos0.reshape(n, q_chunk)

    def body(_, xs):
        qc, pc = xs
        out = _attn_chunk(qc, k, v, pc, k_pos, causal=causal, window=window,
                          cap=cap, kv_valid=kv_valid)
        return 0, out

    # remat the chunk: never store the (B, H, Cq, Tk) probs for backward
    _, outs = jax.lax.scan(jax.checkpoint(body), 0, (qs, ps))
    return outs.swapaxes(0, 1).reshape(b, tq, hq, hd)
