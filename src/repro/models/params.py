"""Parameter descriptor mini-framework.

Models build a pytree of :class:`ParamDef` leaves (a pure function of the
config).  The same tree then yields:

* ``materialize``  -> real initialized arrays (smoke tests / real training),
* ``abstract``     -> ShapeDtypeStructs (dry-run lowering, zero allocation),
* ``shardings``    -> NamedShardings for pjit in_shardings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P = P()
    init: str = "normal"      # normal | zeros | ones | embed
    scale: Optional[float] = None   # None -> 1/sqrt(fan_in)
    dtype: str = "float32"

    @property
    def fan_in(self) -> int:
        # last-but-one dim is fan-in for matmul weights; 1-d params use size
        if len(self.shape) >= 2:
            return self.shape[-2]
        return max(1, self.shape[0])


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _leaves(tree):
    return jax.tree.leaves(tree, is_leaf=is_def)


def materialize(key, tree, dtype=None):
    defs = _leaves(tree)
    keys = jax.random.split(key, max(1, len(defs)))

    def make(d: ParamDef, k):
        dt = dtype or d.dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "embed":
            return (jax.random.normal(k, d.shape, jnp.float32) * 0.02).astype(dt)
        scale = d.scale if d.scale is not None else 1.0 / np.sqrt(d.fan_in)
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt)

    it = iter(keys)
    return jax.tree.map(lambda d: make(d, next(it)), tree, is_leaf=is_def)


def abstract(tree, dtype=None, mesh: Optional[Mesh] = None):
    def mk(d: ParamDef):
        sh = None
        if mesh is not None:
            sh = NamedSharding(mesh, d.spec)
        return jax.ShapeDtypeStruct(d.shape, dtype or d.dtype, sharding=sh)
    return jax.tree.map(mk, tree, is_leaf=is_def)


def shardings(tree, mesh: Mesh):
    return jax.tree.map(lambda d: NamedSharding(mesh, d.spec), tree, is_leaf=is_def)


def specs(tree):
    return jax.tree.map(lambda d: d.spec, tree, is_leaf=is_def)


def with_spec(d: ParamDef, spec: P) -> ParamDef:
    return dataclasses.replace(d, spec=spec)


def count(tree) -> int:
    return sum(int(np.prod(d.shape)) for d in _leaves(tree))
