"""Swappable optimizers behind one functional API.

Every optimizer here is an :class:`repro.core.transform.Optimizer` —
``(init, update, reject)`` — so the trainer, launcher and benchmarks treat
K-FAC and the first-order baselines identically::

    from repro import optimizers
    opt = optimizers.get("kfac", model, kfac_cfg=KFACConfig(...))
    state = opt.init(params, batch)
    new_params, state, metrics = opt.update(None, state, params, batch, rng)
"""
from __future__ import annotations

from repro.core.transform import Optimizer, Transform, TransformState
from repro.optimizers.baselines import (adam, adam_transform, sgd_momentum,
                                        sgd_momentum_transform)
from repro.optimizers.kfac import KFACEngine, KFACPipeline, kfac

__all__ = ["Optimizer", "Transform", "TransformState", "KFACEngine",
           "KFACPipeline", "kfac", "sgd_momentum", "sgd_momentum_transform",
           "adam", "adam_transform", "as_optimizer", "get"]


def as_optimizer(opt) -> Optimizer:
    """Normalize whatever the caller hands the trainer into an Optimizer.

    Accepts an :class:`Optimizer` as-is and wraps a legacy
    ``repro.core.kfac.KFAC`` engine (the deprecation-shim path) into the
    staged pipeline."""
    if isinstance(opt, Optimizer):
        return opt
    if isinstance(opt, KFACEngine):
        return kfac(engine=opt)
    raise TypeError(f"not an optimizer: {type(opt).__name__} (expected an "
                    "Optimizer from repro.optimizers, or a legacy KFAC "
                    "engine)")


def get(name: str, model=None, *, kfac_cfg=None, mesh=None,
        family: str = "categorical", **kw) -> Optimizer:
    """Optimizer registry for launchers: kfac | sgd_momentum | adam."""
    if name == "kfac":
        return kfac(model, kfac_cfg, mesh, family)
    if name in ("sgd", "sgd_momentum"):
        return sgd_momentum(model, **kw)
    if name == "adam":
        return adam(model, **kw)
    raise KeyError(f"unknown optimizer {name!r} "
                   "(expected kfac | sgd_momentum | adam)")
