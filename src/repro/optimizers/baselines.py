"""First-order baselines in the same Optimizer API as the K-FAC pipeline.

The paper's comparison baselines (SGD with momentum, Fig. 10/11; Adam as
the modern diagonal reference) expressed as chained generic transforms —
so ``benchmarks/bench_optimizer_race.py`` can race them through the
*identical* ``Trainer.fit`` loop as K-FAC, with no optimizer-specific
branches in the trainer.
"""
from __future__ import annotations

from repro.core.transform import (Optimizer, Transform, add_decayed_weights,
                                  chain, from_transform, scale,
                                  scale_by_adam, with_momentum)


def sgd_momentum_transform(lr: float = 0.1, momentum: float = 0.9,
                           weight_decay: float = 0.0) -> Transform:
    """Classical heavy-ball: ``v <- m v - lr g; p <- p + v``."""
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts += [scale(-lr), with_momentum(momentum)]
    return chain(*parts)


def adam_transform(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
                   eps: float = 1e-8, weight_decay: float = 0.0) -> Transform:
    """Adam; with ``weight_decay`` the decay is decoupled (AdamW): it is
    added *after* the moment rescaling so it is not normalized by
    ``sqrt(nu)``."""
    parts = [scale_by_adam(b1, b2, eps)]
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(scale(-lr))
    return chain(*parts)


def sgd_momentum(model=None, lr: float = 0.1, momentum: float = 0.9,
                 weight_decay: float = 0.0) -> Optimizer:
    return from_transform(
        sgd_momentum_transform(lr, momentum, weight_decay), model,
        name="sgd_momentum")


def adam(model=None, lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    return from_transform(
        adam_transform(lr, b1, b2, eps, weight_decay), model, name="adam")
