"""K-FAC as a staged gradient-transformation pipeline (paper Algorithm 2).

Two layers:

:class:`KFACEngine`
    The jit-able stage functions, one per paper concern.  Each is a pure
    ``state -> state`` (plus grads/params/batch) map over the typed
    :class:`~repro.core.transform.KFACState`:

      ``stats_grads``       every step: one forward, two backwards (true-
                            label gradients + model-sampled g statistics),
                            running factor update (S5).
      ``refresh_inverses``  every T3 steps (and k<=3): damped structured
                            inverses (S4.2/S6.3); ``refresh_multi`` the
                            stacked gamma-candidate set (S6.6);
                            ``refresh_subset`` the staggered variant.
      ``rescale_step``      eigen mode only, every step: EKFAC second-
                            moment diagonal in the amortized eigenbases
                            (George et al. 1806.03884).
      ``apply_update``      every step: preconditioning fused with the
                            exact-F re-scaling + momentum 2x2 solve
                            (S6.4/S7) and candidate selection by M(delta).
      ``lambda_step``       every T1 steps: reduction ratio rho + LM rule
                            (S6.5).

    Keeping the stages separate (no lax.cond megakernel) keeps the
    per-step HLO — and hence the roofline accounting — honest; the dry-run
    and distributed tests lower them individually via ``Optimizer.engine``.

:func:`kfac`
    Assembles the stages into a trainer-facing
    ``Optimizer(init, update, reject, state_shardings)``: ``update(None,
    state, params, batch, rng)`` runs one full optimizer step, scheduling
    the amortized stages (T1/T2/T3, warmup, staggered refresh,
    stats_period) off the step counter in the state.  The schedule lives
    here — ``Trainer`` no longer hard-codes the five-call K-FAC
    choreography and can race any :class:`Optimizer` (see
    ``repro.optimizers.baselines``).

Per-layer behavior (factor layout, statistics, damped inverses,
preconditioner apply) lives in a ``CurvatureBlock`` from ``core/blocks`` —
the engine only iterates blocks polymorphically.  The shared numerics sit
in ``core/factors.py`` (S3/S5), ``core/inverse.py`` (S4.2/S6.3),
``core/tridiag.py`` (S4.3/App B), ``core/fisher.py`` (S6.4/App C) and
``core/damping.py`` (S6.5/S6.6).  With ``KFACConfig.kernel_backend ==
"pallas"``, dense blocks route their factor accumulation and two-sided
apply through the Pallas kernels in ``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import KFACConfig
from repro.core import damping as D
from repro.core import factors as F
from repro.core import fisher as FI
from repro.core.blocks import TridiagChain, build_blocks
from repro.core.transform import KFACState, Optimizer
from repro.utils import tree as T


def _path_tuple(keypath) -> tuple:
    out = []
    for k in keypath:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "idx"):
            out.append(k.idx)
        else:
            out.append(str(k))
    return tuple(out)


class KFACEngine:
    """model must provide: metas, loss(params, probes, batch, rng, mode),
    probe_shapes(batch), plus `hidden`/`head_weight` (LM) or `logits` (MLP)."""

    def __init__(self, model, cfg: KFACConfig, mesh=None,
                 family: str = "categorical"):
        if cfg.kernel_backend not in ("xla", "pallas"):
            raise ValueError(f"unknown kernel_backend {cfg.kernel_backend!r}"
                             " (expected 'xla' or 'pallas')")
        if cfg.inv_mode not in ("blkdiag", "tridiag", "eigen"):
            raise ValueError(f"unknown inv_mode {cfg.inv_mode!r}"
                             " (expected 'blkdiag', 'tridiag' or 'eigen')")
        if cfg.refresh_mode not in ("serial", "staggered", "sharded",
                                    "overlap"):
            raise ValueError(
                f"unknown refresh_mode {cfg.refresh_mode!r} (expected "
                "'serial', 'staggered', 'sharded' or 'overlap')")
        if cfg.autotune not in ("off", "cache", "force"):
            raise ValueError(f"unknown autotune {cfg.autotune!r}"
                             " (expected 'off', 'cache' or 'force')")
        # legacy knob: staggered_inverse=True was the only way to ask for
        # the round-robin refresh before refresh_mode existed
        self.refresh_mode = ("staggered"
                             if cfg.refresh_mode == "serial"
                             and cfg.staggered_inverse
                             else cfg.refresh_mode)
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.family = family
        self.metas = model.metas
        self.is_lm = hasattr(model, "hidden")
        self.tagged = {m.param_path for m in self.metas.values()}
        self.tridiag = (cfg.inv_mode == "tridiag"
                        and hasattr(model, "layer_order"))
        self.eigen = cfg.inv_mode == "eigen"
        self.blocks = build_blocks(self.metas, cfg)
        self.chain = TridiagChain(model, cfg) if self.tridiag else None
        self._probe_shapes = None
        # backward-pass fusion of the factor statistics (core/fused): the
        # A-side contractions ride the forward via the model's contract_map
        # hooks and the G side via the custom-VJP gg-probes.  Installing the
        # hooks mutates the model's contract maps — models are built per
        # engine in practice; a tridiag engine must not share a model a
        # fused engine already wired.
        self.fused = bool(cfg.fused_stats) and not self.tridiag
        self.fused_names = set()
        if self.fused:
            from repro.core import fused as FU
            cmap = getattr(model, "contract_map", None)
            gmap = getattr(model, "gcontract_map", None)
            if cmap is not None and gmap is not None:
                interpret = jax.default_backend() != "tpu"
                self.fused_names = {n for n, m in self.metas.items()
                                    if FU.fused_eligible(m)}
                for n in sorted(self.fused_names):
                    m = self.metas[n]
                    if n not in cmap:
                        mk = (FU.conv_a_contract if m.kind == "conv"
                              else FU.dense_a_contract)
                        cmap[n] = mk(m, cfg.kernel_backend, interpret,
                                     cfg.autotune)
                    gmap[n] = FU.g_contract(m, cfg.kernel_backend,
                                            interpret, cfg.autotune)

    # ------------------------------------------------------------------
    def n_tokens(self, batch) -> int:
        if not self.is_lm:
            return int(batch["x"].shape[0])
        b, t = batch["tokens"].shape
        if self.model.cfg.frontend == "patch":
            t += self.model.cfg.frontend_tokens
        return int(b * t)

    def _probes(self, batch):
        if self._probe_shapes is None:
            self._probe_shapes = self.model.probe_shapes(
                jax.eval_shape(lambda b: b, batch))
        probes = self.model.make_probes(self._probe_shapes)
        if self.fused_names:
            # fused layers swap the (N, d_out) zero probe for the tiny
            # {"gg": (d_out, d_out)} probe whose VJP cotangent is the
            # already-contracted second moment (core/fused.apply_gprobe)
            from repro.core import fused as FU
            for n in self.fused_names:
                probes[n] = FU.gg_probe(self.metas[n])
        return probes

    def _is_tagged(self, keypath) -> bool:
        return _path_tuple(keypath) in self.tagged

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, params, batch) -> KFACState:
        factors = {name: blk.init_factors()
                   for name, blk in self.blocks.items()}
        if self.chain is not None:
            factors[TridiagChain.CROSS] = self.chain.init_factors()
        diag = jax.tree_util.tree_map_with_path(
            lambda kp, x: (jnp.zeros((0,), jnp.float32) if self._is_tagged(kp)
                           else jnp.zeros_like(x, jnp.float32)), params)
        inv = self._identity_inverses()
        return KFACState(
            step=jnp.int32(0),
            k_stats=jnp.int32(0),
            lam=jnp.float32(self.cfg.lambda_init),
            gamma=jnp.float32(math.sqrt(self.cfg.lambda_init + self.cfg.eta)),
            factors=factors,
            inv=inv,
            diag=diag,
            delta0=T.tree_zeros_like(T.tree_cast(params, jnp.float32)),
            m_delta=jnp.float32(-1.0),
            loss_prev=jnp.float32(0.0),
            staleness=jnp.int32(0),
            # overlap mode double-buffers the inverses; the other refresh
            # modes keep the slot empty (None) and pay no extra state
            inv_pending=(inv if self.refresh_mode == "overlap" else None),
        )

    def _identity_inverses(self):
        if self.eigen:
            return {name: blk.eigen_identity()
                    for name, blk in self.blocks.items()}
        out = {name: blk.identity_inverse()
               for name, blk in self.blocks.items()}
        if self.chain is not None:
            out[TridiagChain.TRI] = self.chain.identity_inverse()
        return out

    def state_shardings(self, state_abs: KFACState, param_shardings, mesh):
        """NamedSharding tree (a KFACState) for the optimizer state.

        Factor/inverse storage is FSDP-spread over `data` and stack/expert/
        block dims over `model` (see CurvatureBlock.factor_specs); diag &
        momentum follow the parameter shardings; scalars replicate."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(mesh, P())
        fs = {name: blk.factor_specs(mesh) for name, blk in self.blocks.items()}
        fac_sh = {name: {"a": NamedSharding(mesh, fs[name]["a"]),
                         "g": NamedSharding(mesh, fs[name]["g"])}
                  for name in self.metas}
        if self.eigen:
            # eigenbases shard like their factors; the eigenbasis diagonals
            # like the weight (None entries pair with the identity bases)
            inv_sh = {
                name: {k: (None if spec is None else NamedSharding(mesh, spec))
                       for k, spec in blk.eigen_specs(mesh).items()}
                for name, blk in self.blocks.items()}
        else:
            inv_sh = {name: {"a_inv": fac_sh[name]["a"],
                             "g_inv": fac_sh[name]["g"]}
                      for name in self.metas}
        if self.chain is not None:
            cross, tri = TridiagChain.CROSS, TridiagChain.TRI
            fac_sh[cross] = jax.tree.map(lambda _: rep,
                                         state_abs.factors[cross])
            inv_sh[tri] = jax.tree.map(lambda _: rep,
                                       state_abs.inv[tri])
        diag_sh = jax.tree.map(
            lambda leaf, sh: rep if leaf.size == 0 else sh,
            state_abs.diag, param_shardings)
        return KFACState(
            step=rep, k_stats=rep, lam=rep, gamma=rep,
            factors=fac_sh, inv=inv_sh, diag=diag_sh,
            delta0=param_shardings,
            m_delta=rep, loss_prev=rep,
            staleness=rep,
            # the pending buffer shards exactly like the live inverses
            inv_pending=(inv_sh if state_abs.inv_pending is not None
                         else None),
        )

    # ------------------------------------------------------------------
    # stats + grads (paper tasks 1–4): a full-batch gradient pass, plus a
    # tau1-subsampled model-sampled-target pass for the factor statistics.
    # The stats pass differentiates only w.r.t. the probes, so its backward
    # is the cheap activation-only chain (no dW products — task 3's C1 cost).
    # ------------------------------------------------------------------
    def _sub_batch(self, batch):
        stride = max(1, round(1.0 / self.cfg.tau1))
        if stride == 1:
            return batch
        # strided slice stays aligned with the batch sharding
        return jax.tree.map(lambda x: x[::stride], batch)

    def _constrain_grads(self, grads):
        """Pin gradients to the parameter storage layout so partial-sum
        reductions lower as reduce-scatters into the FSDP shards rather than
        full all-reduces."""
        if self.mesh is None or not hasattr(self.model, "param_shardings"):
            return grads
        return jax.lax.with_sharding_constraint(
            grads, self.model.param_shardings())

    def stats_grads(self, state: KFACState, params, batch, rng):
        # ---- pass 1: gradients on the full batch (plain mode) ----
        def f1(p):
            (lt, _), aux = self.model.loss(p, None, batch, rng, mode="plain")
            return lt, aux["metrics"]

        (lt, metrics1), grads = jax.value_and_grad(f1, has_aux=True)(params)
        grads = self._constrain_grads(grads)

        # ---- pass 2: tau1-subsampled statistics with sampled targets ----
        sub = self._sub_batch(batch)
        probes = self._probes(sub)
        n = self.n_tokens(sub)
        rng2 = jax.random.fold_in(rng, 1)

        def f2(pr):
            (_, ls), aux = self.model.loss(params, pr, sub, rng2,
                                           mode="collect")
            return ls, aux

        ls, vjp_fn, aux = jax.vjp(f2, probes, has_aux=True)
        (gprobes,) = vjp_fn(jnp.float32(1.0))
        recs = aux["recs"]

        # each block folds its own contribution into the decayed running
        # factors (dense blocks may fuse this through the Pallas kernel)
        k = state.k_stats + 1
        eps = F.decay_eps(k, self.cfg.decay_cap)
        factors = {
            name: blk.update_factors(state.factors[name], recs.get(name),
                                     gprobes.get(name), sub, n, eps)
            for name, blk in self.blocks.items()}
        if self.chain is not None:
            cross = TridiagChain.CROSS
            factors[cross] = self.chain.update_factors(
                state.factors[cross], recs, gprobes, sub, n, eps)

        # diagonal running curvature for untagged (elementwise) params —
        # squared gradients (these cover <1% of parameters; the tagged
        # weights use the proper Kronecker blocks)
        diag_new = jax.tree_util.tree_map_with_path(
            lambda kp, g, old: (old if self._is_tagged(kp)
                                else eps * old
                                + (1 - eps) * jnp.square(g.astype(jnp.float32))),
            grads, state.diag)

        state = state.replace(factors=factors, diag=diag_new, k_stats=k,
                              loss_prev=lt)
        metrics = dict(metrics1, loss_sampled=ls)
        return state, grads, metrics

    # ------------------------------------------------------------------
    # inverses
    # ------------------------------------------------------------------
    def _inverses_for(self, factors, gamma, prev=None):
        cfg = self.cfg
        if self.eigen:
            return {name: blk.eigen_state(factors[name], gamma)
                    for name, blk in self.blocks.items()}
        out = {}
        for name, blk in self.blocks.items():
            out[name] = blk.damped_inverse(
                factors[name], gamma,
                method=cfg.inverse_method, iters=cfg.ns_iters,
                prev=None if prev is None else prev.get(name))
        if self.chain is not None:
            out[TridiagChain.TRI] = self.chain.damped_inverse(factors, gamma)
        return out

    def refresh_inverses(self, state: KFACState, hot: bool = False):
        prev = state.inv if (hot and self.cfg.inverse_method == "ns") else None
        inv = self._inverses_for(state.factors, state.gamma, prev)
        return state.replace(inv=inv)

    def refresh_subset(self, state: KFACState, names, hot: bool = True):
        """Staggered refresh (beyond-paper, DESIGN §3): recompute only the
        named layer blocks — the trainer round-robins so 1/T3 of the d³ work
        lands on each step instead of spiking every T3 steps."""
        cfg = self.cfg
        inv = dict(state.inv)
        if self.eigen:
            for name in names:
                inv[name] = self.blocks[name].eigen_state(
                    state.factors[name], state.gamma)
            return state.replace(inv=inv)
        prev = state.inv if cfg.inverse_method == "ns" and hot else None
        for name in names:
            inv[name] = self.blocks[name].damped_inverse(
                state.factors[name], state.gamma,
                method=cfg.inverse_method,
                iters=cfg.ns_hot_iters if hot else cfg.ns_iters,
                prev=None if prev is None else prev.get(name))
        return state.replace(inv=inv)

    def rescale_step(self, state: KFACState, grads):
        """Eigen mode, every step: re-estimate each block's eigenbasis
        second-moment diagonal from the current gradient (EKFAC's cheap
        half — the bases stay on the amortized T3 schedule).  No-op in the
        other inv_modes."""
        if not self.eigen:
            return state
        eps = jnp.float32(self.cfg.eigen_decay)
        inv = dict(state.inv)
        for name, blk in self.blocks.items():
            v = T.get_path(grads, blk.meta.param_path)
            inv[name] = blk.rescale_step(inv[name], v, eps)
        return state.replace(inv=inv)

    def stagger_groups(self):
        """Partition layer names into T3 staggered refresh groups, balanced
        by the d³ inversion cost model (repro.distributed.plan) instead of
        the old declaration-order round-robin — the per-step refresh work
        is even regardless of how layer sizes interleave."""
        from repro.distributed.plan import build_plan
        return build_plan(self.blocks, max(1, self.cfg.t3)).groups()

    def grads_only(self, state: KFACState, params, batch, rng):
        """Gradient pass without the statistics pass (straggler/budget mode
        via KFACConfig.stats_period)."""
        def f1(p):
            (lt, _), aux = self.model.loss(p, None, batch, rng, mode="plain")
            return lt, aux["metrics"]

        (lt, metrics), grads = jax.value_and_grad(f1, has_aux=True)(params)
        return state.replace(loss_prev=lt), grads, metrics

    def refresh_multi(self, state: KFACState):
        """Stacked inverses for the 3 gamma candidates (S6.6), via vmap.

        Eigen mode shares one eigendecomposition across the candidates —
        the bases are gamma-independent; only the damp diagonal varies."""
        gammas = D.gamma_candidates(state.gamma, self._omega2())
        if self.eigen:
            inv3 = {name: blk.eigen_state_multi(state.factors[name],
                                                gammas)
                    for name, blk in self.blocks.items()}
            return gammas, inv3
        inv3 = jax.vmap(lambda g: self._inverses_for(state.factors, g))(
            gammas)
        return gammas, inv3

    def _omega1(self):
        return float(self.cfg.omega1_base ** self.cfg.t1)

    def _omega2(self):
        return float(math.sqrt(self.cfg.omega2_base) ** self.cfg.t2)

    # ------------------------------------------------------------------
    # preconditioning
    # ------------------------------------------------------------------
    def _precondition(self, grads_reg, inv, state: KFACState):
        lam_eta = state.lam + self.cfg.eta
        # untagged params: diagonal curvature
        out = jax.tree_util.tree_map_with_path(
            lambda kp, g, d: (g if self._is_tagged(kp)
                              else g / (d + lam_eta)),
            grads_reg, state.diag)
        if self.chain is not None:
            vs = {name: T.get_path(grads_reg, self.metas[name].param_path)
                  for name in self.model.layer_order}
            us = self.chain.precondition(inv[TridiagChain.TRI], vs)
            for name, u in us.items():
                out = T.set_path(out, self.metas[name].param_path, u)
        else:
            for name, blk in self.blocks.items():
                v = T.get_path(grads_reg, blk.meta.param_path)
                u = (blk.precondition_eigen(inv[name], v) if self.eigen
                     else blk.precondition(inv[name], v))
                out = T.set_path(out, blk.meta.param_path, u)
        return T.tree_scale(out, -1.0)

    # ------------------------------------------------------------------
    # update: precondition fused with rescale + momentum + candidate select
    # ------------------------------------------------------------------
    def apply_update(self, state: KFACState, params, grads, batch, rng, *,
                     cand_inv: Optional[List] = None, gammas=None,
                     loss_now=None):
        """cand_inv: list of inverse pytrees (candidates); default state.inv.
        Returns (params', state', metrics)."""
        cfg = self.cfg
        invs = cand_inv if cand_inv is not None else [state.inv]
        nc = len(invs)
        grads_reg = T.tree_axpy(cfg.eta, T.tree_cast(params, jnp.float32),
                                T.tree_cast(grads, jnp.float32))

        deltas = [self._precondition(grads_reg, inv, state) for inv in invs]
        use_mom = cfg.use_momentum
        tangents = deltas + ([state.delta0] if use_mom else [])
        m = len(tangents)

        lam_eta = state.lam + cfg.eta
        if cfg.use_rescale:
            if self.is_lm:
                q = FI.quad_lm(self.model, params, batch, tangents)
            else:
                q = FI.quad_logits(
                    lambda p: self.model.logits(p, batch["x"]),
                    params, batch, tangents, self.family)
            dots = jnp.array([[T.tree_dot(tangents[i], tangents[j])
                               for j in range(m)] for i in range(m)])
            q = q + lam_eta * dots
            b = jnp.array([T.tree_dot(grads_reg, t) for t in tangents])

            alphas, mus, ms = [], [], []
            for c in range(nc):
                if use_mom:
                    idx = jnp.array([c, m - 1])
                    q2 = q[jnp.ix_(idx, idx)] + 1e-20 * jnp.eye(2)
                    b2 = b[idx]
                    x = -jnp.linalg.solve(q2, b2)
                    mval = 0.5 * x @ q2 @ x + b2 @ x
                    alphas.append(x[0]); mus.append(x[1]); ms.append(mval)
                else:
                    a = -b[c] / jnp.maximum(q[c, c], 1e-20)
                    alphas.append(a); mus.append(jnp.float32(0.0))
                    ms.append(0.5 * a * a * q[c, c] + a * b[c])
            alphas = jnp.stack(alphas); mus = jnp.stack(mus)
            ms = jnp.stack(ms)
            c_star = jnp.argmin(ms)
            alpha = alphas[c_star]
            mu = mus[c_star]
            m_delta = ms[c_star]
        else:
            alpha = jnp.float32(cfg.fixed_lr)
            mu = jnp.float32(0.0)
            c_star = jnp.int32(0)
            m_delta = jnp.float32(-1.0)

        # select the winning candidate's delta (and inverses / gamma) by
        # indexing the stacked candidates — one gather per leaf
        if nc == 1:
            delta_sel = deltas[0]
            inv_sel = invs[0]
            gamma_new = state.gamma
        else:
            delta_sel = jax.tree.map(
                lambda *xs: jnp.take(jnp.stack(xs), c_star, axis=0), *deltas)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *invs)
            inv_sel = jax.tree.map(lambda x: jnp.take(x, c_star, axis=0),
                                   stacked)
            gamma_new = gammas[c_star]

        delta = T.tree_scale(delta_sel, alpha)
        if use_mom:
            delta = T.tree_axpy(mu, state.delta0, delta)
        new_params = jax.tree.map(
            lambda p, d: (p + d.astype(p.dtype)), params, delta)

        state = state.replace(step=state.step + 1, delta0=delta,
                              m_delta=m_delta, inv=inv_sel, gamma=gamma_new)
        metrics = {
            "alpha": alpha, "mu": mu, "m_delta": m_delta,
            "gamma": gamma_new, "lam": state.lam,
            "grad_norm": jnp.sqrt(T.tree_sqnorm(grads_reg)),
            "delta_norm": jnp.sqrt(T.tree_sqnorm(delta)),
        }
        return new_params, state, metrics

    # ------------------------------------------------------------------
    # fused fixed-lr update chain: precondition + momentum + global clip
    # ------------------------------------------------------------------
    def apply_update_fused(self, state: KFACState, params, grads, batch,
                           rng, *, inv_override=None, gamma_override=None):
        """The ``use_rescale=False`` path as ONE fused stage: per block,
        ``D = −lr·(Ā⁻¹ V G⁻¹) + μ·M`` together with ``Σ D²`` comes out of a
        single ``CurvatureBlock.precond_momentum`` call (Pallas blocks serve
        it with the fused ``update_chain`` kernel), so the global-norm clip
        folds into the parameter apply without ever re-reading the update.

        With ``fixed_momentum == 0``, ``clip_delta_norm == 0`` and
        ``kl_clip == 0`` this is bitwise the legacy three-stage path.  On T2 candidate steps the
        caller passes candidate 0's inverses/gamma (the legacy fixed-lr
        ``c_star = 0`` selection).  Returns (params', state', metrics)."""
        cfg = self.cfg
        inv = inv_override if inv_override is not None else state.inv
        gamma_new = (gamma_override if gamma_override is not None
                     else state.gamma)
        lam_eta = state.lam + cfg.eta
        alpha = -jnp.float32(cfg.fixed_lr)
        mu = jnp.float32(cfg.fixed_momentum)
        grads_reg = T.tree_axpy(cfg.eta, T.tree_cast(params, jnp.float32),
                                T.tree_cast(grads, jnp.float32))
        sqs = []

        # untagged params: diagonal curvature, axpy'd in the same traversal
        def leaf(kp, g, dd, mom):
            if self._is_tagged(kp):
                return mom            # overwritten by the block loop below
            d = alpha * (g / (dd + lam_eta)) + mu * mom
            sqs.append(jnp.sum(d * d))
            return d

        vel = jax.tree_util.tree_map_with_path(leaf, grads_reg, state.diag,
                                               state.delta0)
        if self.chain is not None:
            vs = {name: T.get_path(grads_reg, self.metas[name].param_path)
                  for name in self.model.layer_order}
            us = self.chain.precondition(inv[TridiagChain.TRI], vs)
            for name, blk in self.blocks.items():
                path = blk.meta.param_path
                u = us.get(name, T.get_path(grads_reg, path))
                d = (alpha * u.astype(jnp.float32)
                     + mu * T.get_path(state.delta0, path))
                sqs.append(jnp.sum(d * d))
                vel = T.set_path(vel, path, d)
        else:
            for name, blk in self.blocks.items():
                path = blk.meta.param_path
                d, sq = blk.precond_momentum(
                    inv[name], T.get_path(grads_reg, path),
                    T.get_path(state.delta0, path), alpha, mu,
                    eigen=self.eigen)
                sqs.append(sq)
                vel = T.set_path(vel, path, d)

        norm = jnp.sqrt(sum(sqs) if sqs else jnp.float32(0.0))
        factor = jnp.float32(1.0)
        if cfg.kl_clip > 0:
            # trust region on the Fisher quadratic of the applied step:
            # vel already carries -lr, so |velᵀ∇| ≈ lr²·ΔᵀFΔ and
            # ν = min(1, sqrt(max_kl / |velᵀ∇|))  (transform.with_kl_clip)
            quad = jnp.abs(T.tree_dot(vel, grads_reg))
            factor = factor * jnp.minimum(
                jnp.float32(1.0),
                jnp.sqrt(cfg.kl_clip / jnp.maximum(quad, 1e-20)))
        if cfg.clip_delta_norm > 0:
            factor = factor * jnp.minimum(
                jnp.float32(1.0),
                cfg.clip_delta_norm / jnp.maximum(norm, 1e-20))
        if cfg.kl_clip > 0 or cfg.clip_delta_norm > 0:
            new_params = jax.tree.map(
                lambda p, d: p + (factor * d).astype(p.dtype), params, vel)
            delta_norm = factor * norm
        else:
            new_params = jax.tree.map(
                lambda p, d: p + d.astype(p.dtype), params, vel)
            delta_norm = norm

        # delta0 keeps the PRE-clip velocity (with_momentum semantics)
        state = state.replace(step=state.step + 1, delta0=vel,
                              m_delta=jnp.float32(-1.0), inv=inv,
                              gamma=gamma_new)
        metrics = {
            "alpha": jnp.float32(cfg.fixed_lr), "mu": mu,
            "m_delta": jnp.float32(-1.0), "gamma": gamma_new,
            "lam": state.lam,
            "grad_norm": jnp.sqrt(T.tree_sqnorm(grads_reg)),
            "delta_norm": delta_norm,
        }
        if cfg.kl_clip > 0 or cfg.clip_delta_norm > 0:
            # the applied clip factor nu (1.0 = no clipping bit).  Only
            # added when a clip is configured so the default jitted
            # program's output structure is unchanged.
            metrics["nu"] = factor
        return new_params, state, metrics

    # ------------------------------------------------------------------
    # lambda adaptation (S6.5)
    # ------------------------------------------------------------------
    def lambda_step(self, state: KFACState, new_params, batch, rng):
        (l_new, _), _ = self.model.loss(new_params, None, batch, rng,
                                        mode="plain")
        rho = (l_new - state.loss_prev) / jnp.minimum(
            state.m_delta, -1e-20)
        lam = D.lambda_update(state.lam, rho, self._omega1())
        return state.replace(lam=lam), rho


# ---------------------------------------------------------------------------
# the pipeline: stages + schedule -> Optimizer(init, update, reject)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepContext:
    """Mutable per-step scratch the stages thread their work through."""

    step: int
    warmup: bool
    state: KFACState
    params: Any
    batch: Any
    rng: Any
    grads: Any = None
    new_params: Any = None
    candidates: Any = None          # (gammas, stacked inv3) on T2 steps
    metrics: dict = dataclasses.field(default_factory=dict)


class Stage(NamedTuple):
    name: str
    run: Callable[[StepContext], None]


class KFACPipeline:
    """Drives one optimizer step as an ordered list of named stages.

    Each stage owns its own schedule predicate (read off the concrete step
    counter) and calls a *jitted* engine stage — the composition is
    host-level, so the per-stage HLO stays separate (roofline honesty) and
    the step sequence is bit-identical to driving the engine stages by
    hand, which ``tests/test_transform.py`` pins per ``inv_mode``.
    """

    def __init__(self, engine: KFACEngine, obs=None):
        from repro import obs as obs_mod
        self.engine = eng = engine
        cfg = eng.cfg
        # telemetry (repro.obs): obs=None reads the engine's cfg.obs; pass
        # a shared Obs to land pipeline events in the same log as the
        # trainer's.  Disabled, the spans below are no-op context managers
        # (no clocks, no block_until_ready) and the jitted stages are
        # byte-identical — pinned by tests/test_obs.py.
        self.obs = obs_mod.from_config(obs if obs is not None else cfg.obs)
        self._start: Optional[int] = None
        self._stats = jax.jit(eng.stats_grads)
        self._grads_only = jax.jit(eng.grads_only)
        self._rescale = jax.jit(eng.rescale_step) if eng.eigen else None
        self._refresh = jax.jit(lambda s: eng.refresh_inverses(s, hot=True))
        self._refresh_sub = {
            i: jax.jit(lambda s, ns=tuple(g): eng.refresh_subset(s, ns))
            for i, g in enumerate(eng.stagger_groups())} \
            if eng.refresh_mode == "staggered" else None
        # distributed curvature service (repro.distributed): the sharded
        # block-parallel refresh, plus the async double-buffer controller
        self._refresh_sharded = None
        self._overlap = None
        if eng.refresh_mode in ("sharded", "overlap"):
            from repro.distributed.overlap import OverlapController
            from repro.distributed.refresh import build_sharded_refresh
            self._refresh_sharded = build_sharded_refresh(eng)
            if eng.refresh_mode == "overlap":
                self._overlap = OverlapController(
                    self._refresh_sharded, bound=max(1, cfg.t3),
                    deterministic=cfg.overlap_deterministic, obs=self.obs)
        self._multi = jax.jit(eng.refresh_multi)
        if cfg.use_rescale:
            self._update = jax.jit(
                lambda s, p, g, b, r: eng.apply_update(s, p, g, b, r))
            self._update3 = jax.jit(
                lambda s, p, g, b, r, gs, i3: eng.apply_update(
                    s, p, g, b, r,
                    cand_inv=[jax.tree.map(lambda x: x[c], i3)
                              for c in range(3)],
                    gammas=gs))
            # precondition is fused into the quadratic-model stage: the
            # M(delta) solve needs every candidate's preconditioned delta
            # and the exact-F products in one HLO (S6.4/S6.6)
            update_stage = Stage("precondition+quadratic_model_lr_momentum",
                                 self._stage_quadratic)
        else:
            # fixed-lr path: precondition + momentum + global-norm clip as
            # one fused stage (docs/optimizer_api.md "stage map"); on T2
            # steps the gamma sweep keeps candidate 0 (legacy c_star=0)
            self._update = jax.jit(
                lambda s, p, g, b, r: eng.apply_update_fused(s, p, g, b, r))
            self._update3 = jax.jit(
                lambda s, p, g, b, r, gs, i3: eng.apply_update_fused(
                    s, p, g, b, r,
                    inv_override=jax.tree.map(lambda x: x[0], i3),
                    gamma_override=gs[0]))
            update_stage = Stage("fused_precondition_momentum_clip",
                                 self._stage_quadratic)
        self._lambda = jax.jit(eng.lambda_step)
        self.stages = [
            Stage("estimate_stats", self._stage_estimate_stats),
            Stage("scheduled_inverse_refresh", self._stage_refresh),
            Stage("eigen_rescale", self._stage_eigen_rescale),
            update_stage,
            Stage("adapt_lambda", self._stage_adapt_lambda),
        ]

    # -- stages --------------------------------------------------------
    def _stage_estimate_stats(self, ctx: StepContext):
        if ctx.grads is not None:
            raise ValueError(
                "kfac computes its own gradients (the statistics pass "
                "shares the forward with the gradient pass) — call "
                "update(None, state, params, batch, rng)")
        if ctx.step % self.engine.cfg.stats_period == 0:
            ctx.state, ctx.grads, metrics = self._stats(
                ctx.state, ctx.params, ctx.batch, ctx.rng)
        else:
            # stats skipped (straggler/budget mode): grads only
            ctx.state, ctx.grads, metrics = self._grads_only(
                ctx.state, ctx.params, ctx.batch, ctx.rng)
        ctx.metrics.update(metrics)

    def _full_refresh(self, state: KFACState) -> KFACState:
        """Synchronous full refresh via the mode's executor: the serial
        engine stage, or the block-parallel sharded service."""
        sharded = self._refresh_sharded is not None
        mode = "sharded" if sharded else "serial"
        with self.obs.span(f"refresh/{mode}",
                           block=lambda: out.inv) as sp:
            if sharded:
                inv = self._refresh_sharded(state.factors, state.gamma,
                                            state.inv)
                out = state.replace(inv=inv)
            else:
                out = self._refresh(state)
        if self.obs.enabled:
            payload = {"mode": mode, "wall_s": sp.seconds}
            plan = getattr(self._refresh_sharded, "plan", None)
            if plan is not None:
                payload.update(n_shards=plan.n_shards,
                               serial_cost=float(plan.serial_cost()),
                               parallel_cost=float(plan.parallel_cost()))
            self.obs.emit("refresh", **payload)
        return out

    def _stage_refresh(self, ctx: StepContext):
        cfg = self.engine.cfg
        if cfg.t2 > 0 and ctx.step > 0 and ctx.step % cfg.t2 == 0:
            # gamma sweep (S6.6): stacked candidate inverses; selection
            # happens inside the quadratic-model stage
            with self.obs.span("refresh/gamma_sweep",
                               block=lambda: ctx.candidates):
                ctx.candidates = self._multi(ctx.state)
            if self._overlap is not None:
                # the sweep recomputes inverses synchronously from the
                # current factors — an older in-flight buffer must not
                # overwrite them later
                self._overlap.cancel(ctx.step)
                ctx.state = ctx.state.replace(staleness=jnp.int32(0))
        elif self._overlap is not None and not ctx.warmup:
            ctl = self._overlap
            commits0 = ctl.n_commits
            ctx.state = ctl.on_refresh_stage(
                ctx.state, ctx.step, due=(ctx.step % cfg.t3 == 0))
            ctx.metrics["staleness"] = ctx.state.staleness
            if self.obs.enabled and ctl.n_commits > commits0:
                # an async buffer just swapped in: the dispatch->commit
                # wall (+ whether the commit had to block) is the refresh
                self.obs.emit("refresh", mode="overlap",
                              wall_s=ctl.last_refresh_s,
                              forced=ctl.last_forced,
                              staleness=int(ctx.state.staleness),
                              n_cancelled=ctl.n_cancelled)
        elif ctx.warmup:
            ctx.state = self._full_refresh(ctx.state)
        elif self._refresh_sub is not None:
            # staggered: 1/T3 of the layer inverses per step, groups
            # balanced by the d³ cost model
            group = ctx.step % cfg.t3
            with self.obs.span("refresh/staggered",
                               block=lambda: ctx.state.inv) as sp:
                ctx.state = self._refresh_sub[group](ctx.state)
            if self.obs.enabled:
                self.obs.emit("refresh", mode="staggered",
                              wall_s=sp.seconds, group=group)
        elif ctx.step % cfg.t3 == 0:
            ctx.state = self._full_refresh(ctx.state)

    def _stage_eigen_rescale(self, ctx: StepContext):
        if self._rescale is not None and ctx.candidates is None:
            # eigen mode: per-step EKFAC diagonal re-estimation in the
            # (amortized) eigenbases
            ctx.state = self._rescale(ctx.state, ctx.grads)

    def _stage_quadratic(self, ctx: StepContext):
        if ctx.candidates is not None:
            gs, i3 = ctx.candidates
            ctx.new_params, ctx.state, um = self._update3(
                ctx.state, ctx.params, ctx.grads, ctx.batch, ctx.rng, gs, i3)
        else:
            ctx.new_params, ctx.state, um = self._update(
                ctx.state, ctx.params, ctx.grads, ctx.batch, ctx.rng)
        ctx.metrics.update(um)

    def _stage_adapt_lambda(self, ctx: StepContext):
        cfg = self.engine.cfg
        if cfg.t1 > 0 and (ctx.step + 1) % cfg.t1 == 0:
            # a non-finite update will be rejected by the trainer: evaluate
            # rho at the params it will actually keep, as the pre-redesign
            # trainer (guard before lambda_step) did
            target = (ctx.new_params if bool(T.tree_isfinite(ctx.new_params))
                      else ctx.params)
            ctx.state, rho = self._lambda(ctx.state, target,
                                          ctx.batch, ctx.rng)
            ctx.metrics["rho"] = rho

    # -- Optimizer protocol --------------------------------------------
    def init(self, params, batch) -> KFACState:
        self._start = None            # new run: re-arm the warmup refreshes
        if self._overlap is not None:
            self._overlap.reset()     # drop any in-flight refresh buffer
        return self.engine.init(params, batch)

    def poll(self, state: KFACState) -> KFACState:
        """Trainer end-of-step hook: commit a finished async refresh
        buffer (overlap mode); never blocks, no-op otherwise."""
        if self._overlap is not None and isinstance(state, KFACState):
            return self._overlap.poll(state)
        return state

    def update(self, grads, state: KFACState, params, batch, rng):
        step = int(state.step)        # schedule off the state, not a loop var
        if self._start is None:
            self._start = step
        ctx = StepContext(step=step, warmup=step - self._start < 3,
                          state=state, params=params, batch=batch, rng=rng,
                          grads=grads)
        if not self.obs.enabled:
            for stage in self.stages:
                stage.run(ctx)
            return ctx.new_params, ctx.state, ctx.metrics
        # instrumented path: per-stage wall time (host-side, blocking on
        # the stage's outputs at span close — the jitted programs are the
        # same; only the host gains sync points) + one kfac_step event
        stage_s = {}
        for stage in self.stages:
            blk = lambda: [x for x in (ctx.state, ctx.grads,
                                       ctx.new_params) if x is not None]
            with self.obs.span(f"kfac/{stage.name}", block=blk) as sp:
                stage.run(ctx)
            stage_s[stage.name] = sp.seconds
        self.obs.emit("kfac_step", step=step, stages=stage_s)
        return ctx.new_params, ctx.state, ctx.metrics

    def reject(self, state: KFACState) -> KFACState:
        """Non-finite update was skipped: raise damping, drop momentum."""
        return state.replace(lam=state.lam * 4.0,
                             delta0=T.tree_zeros_like(state.delta0))


def kfac(model=None, cfg: Optional[KFACConfig] = None, mesh=None,
         family: str = "categorical", *,
         engine: Optional[KFACEngine] = None, obs=None) -> Optimizer:
    """Build the K-FAC optimizer pipeline as an ``Optimizer``.

        opt = kfac(model, KFACConfig(...))
        state = opt.init(params, batch)
        new_params, state, metrics = opt.update(None, state, params,
                                                batch, rng)

    Pass ``engine=`` to wrap an already-constructed :class:`KFACEngine`
    (the legacy ``repro.core.kfac.KFAC`` class is the same object); pass
    ``obs=`` (an ``repro.obs.Obs`` or ``ObsConfig``) to share one
    telemetry registry/log with the trainer — defaults to the engine's
    ``cfg.obs``."""
    eng = engine if engine is not None else KFACEngine(model, cfg or
                                                       KFACConfig(),
                                                       mesh, family)
    pipe = KFACPipeline(eng, obs=obs)
    return Optimizer(init=pipe.init, update=pipe.update, reject=pipe.reject,
                     state_shardings=eng.state_shardings,
                     poll=pipe.poll if eng.refresh_mode == "overlap" else None,
                     engine=eng, name=f"kfac_{eng.cfg.inv_mode}")
