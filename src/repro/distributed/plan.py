"""RefreshPlan: cost-model bin-packing of curvature blocks over shards.

The paper's headline economics rest on amortizing the d³ factor inversions
(S8: computed "only occasionally") — and on the observation that per-layer
inverses are *independent*, so the Σd³ refresh spike parallelizes across
devices.  This module owns the assignment: every curvature block gets a
scalar inversion-cost estimate from its factor layout (the same
``LayerMeta`` shape metadata the block registry dispatches on), and
:func:`bin_pack` spreads the blocks across ``n_shards`` bins with the
longest-processing-time greedy rule.

The same planner also balances the *temporal* round-robin
(``KFACEngine.stagger_groups``): T3 staggered-refresh groups are bins too,
so the per-step d³ work is even instead of whatever layer-declaration
order happened to produce.

Greedy LPT gives the classical guarantee used by the balance property
test: ``max_load − max_single_cost ≤ min_load`` — no bin exceeds the
ideal by more than one block, so the max/min device cost ratio is bounded
whenever no single block dominates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping

# pseudo-block key for the tridiagonal chain's Ψ/Σ precompute (owned by a
# single shard like any block; it needs every layer's factors, which the
# sharded refresh replicates anyway)
CHAIN = "__chain__"


def matrix_inverse_cost(dim: int, kind: str, blocks: int, lead: int) -> float:
    """O(d³)-model cost of inverting/eigendecomposing one factor side.

    ``diag`` factors cost d (elementwise reciprocal); ``block`` factors
    invert `blocks` independent (d/blocks)² matrices; full factors d³.
    ``lead`` multiplies in the stacked/expert batch dims.
    """
    if kind == "diag":
        return float(lead * dim)
    if kind == "block":
        blocks = max(1, blocks)
        return float(lead * blocks * (dim // blocks) ** 3)
    return float(lead * dim ** 3)


def block_cost(meta) -> float:
    """d³ refresh cost of one curvature block (both factor sides)."""
    lead = max(1, meta.n_stack) * max(1, meta.n_expert)
    return (matrix_inverse_cost(meta.a_dim, meta.a_kind, meta.a_blocks, lead)
            + matrix_inverse_cost(meta.g_dim, meta.g_kind, meta.g_blocks,
                                  lead))


def bin_pack(costs: Mapping[str, float], n_bins: int) -> Dict[str, int]:
    """Deterministic LPT greedy: heaviest item first, into the least-loaded
    bin (ties by bin index; item ties by name).  Guarantees
    ``max_load - max(costs) <= min_load``."""
    if n_bins < 1:
        raise ValueError(f"n_bins must be >= 1, got {n_bins}")
    loads = [0.0] * n_bins
    owners: Dict[str, int] = {}
    for name in sorted(costs, key=lambda k: (-costs[k], str(k))):
        b = min(range(n_bins), key=lambda i: (loads[i], i))
        owners[name] = b
        loads[b] += costs[name]
    return owners


@dataclasses.dataclass(frozen=True)
class RefreshPlan:
    """Assignment of curvature blocks to refresh shards.

    ``owners[name]`` is the shard that computes block ``name``'s damped
    inverse / eigen state; ``costs[name]`` the d³ model cost it was
    packed by.  The plan is pure metadata — :mod:`.refresh` turns it into
    the shard_map program, and ``KFACEngine.stagger_groups`` reuses it
    with ``n_shards = T3`` for the temporal round-robin.
    """

    n_shards: int
    owners: Mapping[str, int]
    costs: Mapping[str, float]

    def groups(self) -> List[List[str]]:
        """Per-shard block-name lists (deterministic order)."""
        out: List[List[str]] = [[] for _ in range(self.n_shards)]
        for name in sorted(self.owners):
            out[self.owners[name]].append(name)
        return out

    def shard_costs(self) -> List[float]:
        loads = [0.0] * self.n_shards
        for name, shard in self.owners.items():
            loads[shard] += self.costs[name]
        return loads

    def balance_ratio(self) -> float:
        """max/min shard cost over *loaded* shards (inf if degenerate)."""
        loaded = [c for c in self.shard_costs() if c > 0]
        if not loaded:
            return 1.0
        return max(loaded) / min(loaded)

    def serial_cost(self) -> float:
        return sum(self.costs.values())

    def parallel_cost(self) -> float:
        """Critical-path cost: the most-loaded shard (~Σd³/P when even)."""
        return max(self.shard_costs() or [0.0])


def build_plan(blocks: Mapping[str, object], n_shards: int, *,
               chain: bool = False) -> RefreshPlan:
    """Bin-pack the registry's blocks over ``n_shards`` by d³ cost.

    ``chain=True`` adds the tridiagonal-chain precompute (:data:`CHAIN`)
    as one more ownable unit, costed like a full serial pass over the
    layer blocks (TRI.precompute touches every layer's factors).
    """
    costs = {name: block_cost(blk.meta) for name, blk in blocks.items()}
    if chain:
        costs[CHAIN] = max(sum(costs.values()), 1.0)
    owners = bin_pack(costs, n_shards)
    return RefreshPlan(n_shards=n_shards, owners=owners, costs=costs)
