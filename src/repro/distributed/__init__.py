"""Distributed curvature service: plan / sharded refresh / async overlap.

Selected via ``KFACConfig.refresh_mode``:

  * ``serial``    — every device recomputes every inverse on T3 steps
                    (the paper's baseline schedule);
  * ``staggered`` — temporal amortization: 1/T3 of the blocks per step,
                    groups balanced by the d³ cost model;
  * ``sharded``   — spatial: :mod:`.refresh` shard_maps the block set
                    over the mesh (Σd³ → ~Σd³/P), bitwise-identical
                    results;
  * ``overlap``   — :mod:`.overlap` dispatches the sharded refresh
                    asynchronously and double-buffers the swap under an
                    explicit bounded-staleness counter.

See ``docs/distributed.md``.
"""
from repro.distributed.overlap import OverlapController
from repro.distributed.plan import (CHAIN, RefreshPlan, bin_pack, block_cost,
                                    build_plan, matrix_inverse_cost)
from repro.distributed.refresh import build_sharded_refresh, flat_refresh_mesh

__all__ = ["CHAIN", "RefreshPlan", "bin_pack", "block_cost", "build_plan",
           "matrix_inverse_cost", "build_sharded_refresh",
           "flat_refresh_mesh", "OverlapController"]
