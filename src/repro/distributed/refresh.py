"""Block-parallel inverse refresh: shard_map over a flat device mesh.

The serial refresh computes every block's damped inverse (or EKFAC eigen
state) on every device — Σd³ work replicated P times.  Here each device
computes only the blocks a :class:`~repro.distributed.plan.RefreshPlan`
assigns it (``lax.cond`` keeps the unowned branches out of the device's
runtime work) and an all-gather — spelled as a ``psum`` of
owner-computed-else-zero trees — replicates the finished inverses back to
everyone.  Per-device work drops to ~Σd³/P (the plan's critical path).

The refresh runs on its *own* flat 1-axis mesh over the same devices as
the training mesh: it is dispatched as a separate jitted computation
anyway (serially on T3 steps in ``refresh_mode="sharded"``, asynchronously
in ``"overlap"``), so jit reshards the factor inputs in (they are small
next to the weights) and the output inverses land replicated, exactly like
the serial refresh produced them.

Numerics contract: each block's inverse is computed by exactly one device
with the same per-block math the serial path uses (``blk.damped_inverse``
/ ``blk.eigen_state``), and the combining psum only ever adds exact zeros
— so the sharded refresh is bitwise-identical to the serial one (pinned by
``tests/test_refresh_service.py`` on 1 device and
``tests/test_distributed_numerics.py`` on a forced 8-device CPU mesh).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.plan import CHAIN, RefreshPlan, build_plan

AXIS = "shard"


def flat_refresh_mesh(mesh: Optional[Mesh] = None) -> Mesh:
    """1-axis ("shard",) mesh over the training mesh's devices (or all
    local devices when training runs meshless, e.g. CPU tests)."""
    devs = (np.asarray(mesh.devices).reshape(-1) if mesh is not None
            else np.asarray(jax.devices()))
    return Mesh(devs, (AXIS,))


def _zeros_like_shape(shapes):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def _owned(owner: int, compute, operand):
    """Run ``compute(operand)`` only on the owning shard; zeros elsewhere.

    ``lax.cond`` on the runtime ``axis_index`` keeps the d³ work off the
    7/8ths of devices that don't own the block — a ``where`` would compute
    everywhere and only *select* per device.
    """
    idx = jax.lax.axis_index(AXIS)
    shapes = jax.eval_shape(compute, operand)
    return jax.lax.cond(idx == jnp.uint32(owner), compute,
                        lambda _: _zeros_like_shape(shapes), operand)


def build_sharded_refresh(engine, mesh: Optional[Mesh] = None,
                          plan: Optional[RefreshPlan] = None):
    """Compile the block-parallel refresh for ``engine``.

    Returns a jitted ``refresh(factors, gamma, prev=None) -> inv`` whose
    output pytree matches ``KFACState.inv`` for the engine's ``inv_mode``
    (damped inverses, eigen states, plus the tridiagonal Ψ/Σ cache when
    the model has a chain).  ``prev`` is the previous inverse tree and is
    only consulted for Newton–Schulz hot starts (``inverse_method="ns"``),
    mirroring ``KFACEngine.refresh_inverses(hot=True)``.

    Attributes on the returned callable: ``.plan`` (the
    :class:`RefreshPlan`), ``.mesh`` (the flat shard mesh) and
    ``.lower(...)`` (for dry-run cost accounting).
    """
    cfg = engine.cfg
    blocks = engine.blocks
    chain = engine.chain
    eigen = engine.eigen
    use_prev = (not eigen) and cfg.inverse_method == "ns"
    fmesh = flat_refresh_mesh(mesh if mesh is not None else engine.mesh)
    if plan is None:
        plan = build_plan(blocks, fmesh.devices.size, chain=chain is not None)

    def _one_block(blk, fac, gamma, prev_blk):
        if eigen:
            return blk.eigen_state(fac, gamma)
        return blk.damped_inverse(fac, gamma, method=cfg.inverse_method,
                                  iters=cfg.ns_iters, prev=prev_blk)

    def _sharded(factors, gamma, prev):
        out = {}
        for name, blk in blocks.items():
            prev_blk = None if prev is None else prev.get(name)
            out[name] = _owned(
                plan.owners[name],
                lambda op, blk=blk: _one_block(blk, op[0], op[1], op[2]),
                (factors[name], gamma, prev_blk))
        if chain is not None:
            out[chain.TRI] = _owned(
                plan.owners[CHAIN],
                lambda op: chain.damped_inverse(op[0], op[1]),
                (factors, gamma))
        return jax.lax.psum(out, AXIS)

    mapped = shard_map(_sharded, mesh=fmesh, in_specs=(P(), P(), P()),
                       out_specs=P(), check_rep=False)
    jitted = jax.jit(mapped)

    def refresh(factors, gamma, prev=None):
        return jitted(factors, gamma, prev if use_prev else None)

    refresh.plan = plan
    refresh.mesh = fmesh
    refresh.lower = lambda factors, gamma, prev=None: jitted.lower(
        factors, gamma, prev if use_prev else None)
    return refresh
