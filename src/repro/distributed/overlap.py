"""Async double-buffered refresh: overlap the d³ work with training steps.

``refresh_mode="overlap"`` turns the T3 refresh from a synchronous spike
into a pipelined side computation:

  * on a refresh-due step the controller *dispatches* the (sharded)
    refresh against a snapshot of the current factors — jax arrays are
    immutable, so the dispatched computation holds the snapshot for free —
    and the trainer keeps stepping on the previous inverses;
  * every step the controller polls the in-flight buffer
    (``jax.Array.is_ready``) and, once complete, swaps it into
    ``KFACState.inv`` / ``inv_pending`` (the double buffer);
  * ``KFACState.staleness`` counts the steps the in-flight refresh has
    been pending.  It is *bounded*: when it reaches ``bound`` (= T3, the
    next due step) the controller blocks on the buffer and commits, so
    the preconditioner never runs more than one refresh period behind
    its statistics — the staleness contract EKFAC's amortized eigenbases
    (George et al. 1806.03884) already assume for the T3 schedule.

The controller is host-level state owned by the ``KFACPipeline`` (the
stage composition is host-driven by design); the swap itself is a pure
``state.replace``, checkpointable mid-flight (an in-flight dispatch is
simply lost on restore and re-issued at the next due step).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _all_ready(tree) -> bool:
    return all(leaf.is_ready() for leaf in jax.tree.leaves(tree)
               if hasattr(leaf, "is_ready"))


class OverlapController:
    """Double-buffered refresh scheduling for one ``KFACPipeline``.

    ``refresh_fn(factors, gamma, prev) -> inv`` is the (jitted, usually
    sharded) refresh; ``bound`` the staleness ceiling in steps.

    ``deterministic=True`` drops the opportunistic ``is_ready`` commits:
    the buffer swaps in exactly at the next due step (blocking), so the
    trajectory is a pure function of the schedule — wall-clock and host
    load stop mattering.  Slightly staler on average, but reproducible;
    the golden overlap envelope is pinned in this mode.
    """

    def __init__(self, refresh_fn, bound: int, deterministic: bool = False):
        self.refresh_fn = refresh_fn
        self.bound = max(1, int(bound))
        self.deterministic = deterministic
        self.pending: Optional[Tuple[int, object]] = None

    # ------------------------------------------------------------------
    def reset(self):
        """New run (``opt.init``): drop any in-flight buffer."""
        self.pending = None

    def cancel(self):
        """A synchronous recompute (T2 gamma sweep) superseded the
        in-flight refresh — committing it later would roll inverses
        *back*, so drop it."""
        self.pending = None

    # ------------------------------------------------------------------
    def _commit(self, state, inv):
        self.pending = None
        return state.replace(inv=inv, inv_pending=inv,
                             staleness=jnp.int32(0))

    def poll(self, state):
        """Opportunistic swap (the trainer's per-step hook): commit the
        pending buffer iff it finished; never blocks.  No-op in
        deterministic mode — swaps happen on the schedule alone."""
        if self.pending is None or self.deterministic:
            return state
        _, inv = self.pending
        if _all_ready(inv):
            return self._commit(state, inv)
        return state

    def on_refresh_stage(self, state, step: int, due: bool):
        """The pipeline's refresh-stage entry, every step.

        Commit the in-flight buffer when it is ready — or force it
        (block) when the staleness bound is hit or a new dispatch is due.
        Then, on due steps, dispatch the next refresh from the current
        factors (hot-started from the just-committed inverses).
        """
        if self.pending is not None:
            dispatched, inv = self.pending
            age = step - dispatched
            ready = (not self.deterministic) and _all_ready(inv)
            if due or age >= self.bound or ready:
                jax.block_until_ready(inv)
                state = self._commit(state, inv)
            else:
                state = state.replace(staleness=jnp.int32(age))
        if due and self.pending is None:
            inv = self.refresh_fn(state.factors, state.gamma, state.inv)
            self.pending = (step, inv)
            state = state.replace(staleness=jnp.int32(0))
        return state
