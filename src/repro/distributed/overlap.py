"""Async double-buffered refresh: overlap the d³ work with training steps.

``refresh_mode="overlap"`` turns the T3 refresh from a synchronous spike
into a pipelined side computation:

  * on a refresh-due step the controller *dispatches* the (sharded)
    refresh against a snapshot of the current factors — jax arrays are
    immutable, so the dispatched computation holds the snapshot for free —
    and the trainer keeps stepping on the previous inverses;
  * every step the controller polls the in-flight buffer
    (``jax.Array.is_ready``) and, once complete, swaps it into
    ``KFACState.inv`` / ``inv_pending`` (the double buffer);
  * ``KFACState.staleness`` counts the steps the in-flight refresh has
    been pending.  It is *bounded*: when it reaches ``bound`` (= T3, the
    next due step) the controller blocks on the buffer and commits, so
    the preconditioner never runs more than one refresh period behind
    its statistics — the staleness contract EKFAC's amortized eigenbases
    (George et al. 1806.03884) already assume for the T3 schedule.

The controller is host-level state owned by the ``KFACPipeline`` (the
stage composition is host-driven by design); the swap itself is a pure
``state.replace``, checkpointable mid-flight (an in-flight dispatch is
simply lost on restore and re-issued at the next due step).

Telemetry: the controller's lifecycle state is **public** —
``n_commits`` / ``n_forced_commits`` / ``n_cancelled`` /
``cancelled_age_steps`` counters and ``last_staleness`` — and mirrors
into an :class:`repro.obs.Obs` registry when one is attached
(``overlap/commits``, ``overlap/forced_commits``,
``overlap/cancelled_buffers``, ``overlap/staleness_steps`` gauge, and
the ``overlap/refresh_s`` / ``overlap/cancelled_buffer_s`` wall-time
histograms).  A cancelled in-flight buffer's timing is *counted*, not
discarded: the dispatch-to-cancel wall time and its age in steps are
recorded before the buffer is dropped.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _all_ready(tree) -> bool:
    return all(leaf.is_ready() for leaf in jax.tree.leaves(tree)
               if hasattr(leaf, "is_ready"))


class OverlapController:
    """Double-buffered refresh scheduling for one ``KFACPipeline``.

    ``refresh_fn(factors, gamma, prev) -> inv`` is the (jitted, usually
    sharded) refresh; ``bound`` the staleness ceiling in steps.

    ``deterministic=True`` drops the opportunistic ``is_ready`` commits:
    the buffer swaps in exactly at the next due step (blocking), so the
    trajectory is a pure function of the schedule — wall-clock and host
    load stop mattering.  Slightly staler on average, but reproducible;
    the golden overlap envelope is pinned in this mode.
    """

    def __init__(self, refresh_fn, bound: int, deterministic: bool = False,
                 obs=None):
        from repro import obs as obs_mod
        self.refresh_fn = refresh_fn
        self.bound = max(1, int(bound))
        self.deterministic = deterministic
        # (dispatch step, dispatch wall time, in-flight inverse buffer)
        self.pending: Optional[Tuple[int, float, object]] = None
        # public lifecycle counters (mirrored into the obs registry)
        self.n_commits = 0
        self.n_forced_commits = 0
        self.n_cancelled = 0
        self.cancelled_age_steps = 0
        self.last_staleness = 0
        self.last_refresh_s = 0.0     # dispatch->commit wall of last commit
        self.last_forced = False      # last commit had to block (not ready)
        self.obs = obs_mod.from_config(obs)
        self._c_commits = self.obs.counter("overlap/commits")
        self._c_forced = self.obs.counter("overlap/forced_commits")
        self._c_cancelled = self.obs.counter("overlap/cancelled_buffers")
        self._g_staleness = self.obs.gauge("overlap/staleness_steps")
        self._h_refresh = self.obs.histogram("overlap/refresh_s")
        self._h_cancelled = self.obs.histogram("overlap/cancelled_buffer_s")

    # ------------------------------------------------------------------
    def reset(self):
        """New run (``opt.init``): drop any in-flight buffer."""
        self.pending = None
        self._set_staleness(0)

    def cancel(self, step: Optional[int] = None):
        """A synchronous recompute (T2 gamma sweep) superseded the
        in-flight refresh — committing it later would roll inverses
        *back*, so drop it.  The abandoned buffer's timing is counted
        (wall time in flight + age in steps), not silently discarded."""
        if self.pending is not None:
            dispatched, t0, _ = self.pending
            self.n_cancelled += 1
            self._c_cancelled.inc()
            self._h_cancelled.observe(time.perf_counter() - t0)
            if step is not None:
                self.cancelled_age_steps += max(0, step - dispatched)
        self.pending = None
        self._set_staleness(0)

    # ------------------------------------------------------------------
    def _set_staleness(self, steps: int):
        self.last_staleness = int(steps)
        self._g_staleness.set(steps)

    def _commit(self, state, inv, *, forced: bool = False):
        _, t0, _ = self.pending
        self.pending = None
        self.n_commits += 1
        self._c_commits.inc()
        self.last_forced = forced
        if forced:
            self.n_forced_commits += 1
            self._c_forced.inc()
        self.last_refresh_s = time.perf_counter() - t0
        self._h_refresh.observe(self.last_refresh_s)
        self._set_staleness(0)
        return state.replace(inv=inv, inv_pending=inv,
                             staleness=jnp.int32(0))

    def poll(self, state):
        """Opportunistic swap (the trainer's per-step hook): commit the
        pending buffer iff it finished; never blocks.  No-op in
        deterministic mode — swaps happen on the schedule alone."""
        if self.pending is None or self.deterministic:
            return state
        _, _, inv = self.pending
        if _all_ready(inv):
            return self._commit(state, inv)
        return state

    def on_refresh_stage(self, state, step: int, due: bool):
        """The pipeline's refresh-stage entry, every step.

        Commit the in-flight buffer when it is ready — or force it
        (block) when the staleness bound is hit or a new dispatch is due.
        Then, on due steps, dispatch the next refresh from the current
        factors (hot-started from the just-committed inverses).
        """
        if self.pending is not None:
            dispatched, _, inv = self.pending
            age = step - dispatched
            ready = (not self.deterministic) and _all_ready(inv)
            if due or age >= self.bound or ready:
                jax.block_until_ready(inv)
                state = self._commit(state, inv, forced=not ready)
            else:
                self._set_staleness(age)
                state = state.replace(staleness=jnp.int32(age))
        if due and self.pending is None:
            inv = self.refresh_fn(state.factors, state.gamma, state.inv)
            self.pending = (step, time.perf_counter(), inv)
            self._set_staleness(0)
            state = state.replace(staleness=jnp.int32(0))
        return state
