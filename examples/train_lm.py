"""End-to-end LM training driver: ~100M-param llama on synthetic tokens with
the full production stack (trainer, checkpointing, K-FAC schedule).

CPU demo (reduced width, a few hundred steps is feasible but slow; default
keeps it short):

    PYTHONPATH=src python examples/train_lm.py --steps 30

Real hardware: bump --width/--layers (or use --arch full configs through
repro.launch.train) and pass --mesh production.
"""
import argparse

import jax

from repro import optimizers
from repro.configs import get_reduced_config
from repro.configs.base import KFACConfig, TrainConfig
from repro.data.pipeline import SyntheticLMData
from repro.models.lm import LM
from repro.training.checkpoint import Checkpointer
from repro.training.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get_reduced_config("llama3.2-1b").replace(
        name="llama-demo", n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
        head_dim=16, d_ff=384, vocab_size=1024)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    print(f"[train_lm] params: {lm.n_params():,}")

    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch, noise=0.05)
    kcfg = KFACConfig(lambda_init=10.0, t3=5, t1=5, t2=1000)
    tcfg = TrainConfig(steps=args.steps, checkpoint_every=10, log_every=5)
    trainer = Trainer(lm, optimizers.kfac(lm, kcfg), tcfg, None,
                      Checkpointer(args.ckpt))
    out = trainer.fit(params, data, args.steps)
    h = out["history"]
    print(f"[train_lm] loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"({out['seconds']:.1f}s, {len(h)} steps)")
    assert h[-1]["loss"] < h[0]["loss"]


if __name__ == "__main__":
    main()
