"""Batched serving example: continuous-batching engine over a small model.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.configs import get_reduced_config
from repro.models.lm import LM
from repro.serving.server import Engine, Request

cfg = get_reduced_config("smollm-135m")
lm = LM(cfg)
params = lm.init_params(jax.random.PRNGKey(0))

engine = Engine(lm, params, batch_slots=4, max_len=64)
requests = [
    Request(uid=i, prompt=[(3 * i + j) % cfg.vocab_size for j in range(5)],
            max_new=6, temperature=0.0 if i % 2 == 0 else 0.7)
    for i in range(7)
]
engine.run(requests)
for r in requests:
    print(f"req {r.uid}: prompt={r.prompt} -> {r.out}")
done = sum(r.done for r in requests)
print(f"completed {done}/{len(requests)} requests "
      f"(slots=4, continuous refill)")
assert done == len(requests)
