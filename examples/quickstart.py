"""Quickstart: K-FAC on a small MLP in ~30 lines.

The optimizer is a functional ``Optimizer(init, update)`` pipeline
(optax-style): ``update`` runs the paper's full Algorithm 2 schedule —
stats+grads every step, amortized inverse refreshes every T3 steps, the
gamma sweep every T2, the LM lambda rule every T1 — off the step counter
in the typed ``KFACState``.  Swap ``optimizers.kfac`` for
``optimizers.sgd_momentum`` / ``optimizers.adam`` and nothing else
changes; see docs/optimizer_api.md for the stage map.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import optimizers
from repro.configs.base import KFACConfig
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP

# 1. a model with K-FAC-tagged layers (any LM from repro.models.lm works too)
mlp = MLP([32, 16, 8, 16, 32], nonlin="tanh", loss="bernoulli")
params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)

# 2. data + the optimizer (paper hyper-parameters in KFACConfig)
data = SyntheticAutoencoderData(32, 6, 512)
batch = data.batch(0)
opt = optimizers.kfac(mlp, KFACConfig(lambda_init=1.0, t3=5),
                      family="bernoulli")
state = opt.init(params, batch)

# 3. one call per step — the pipeline schedules the amortized stages
for step in range(20):
    rng = jax.random.fold_in(jax.random.PRNGKey(1), step)
    params, state, metrics = opt.update(None, state, params, batch, rng)
    print(f"step {step:2d}  loss={float(metrics['loss']):.4f}  "
          f"alpha={float(metrics['alpha']):.2e}  "
          f"mu={float(metrics['mu']):.2e}  "
          f"lambda={float(state.lam):.3f}")
