"""Quickstart: K-FAC on a small MLP in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import KFACConfig
from repro.core.kfac import KFAC
from repro.data.pipeline import SyntheticAutoencoderData
from repro.models.mlp import MLP

# 1. a model with K-FAC-tagged layers (any LM from repro.models.lm works too)
mlp = MLP([32, 16, 8, 16, 32], nonlin="tanh", loss="bernoulli")
params = mlp.init_params(jax.random.PRNGKey(0), sparse=False)

# 2. data + the optimizer (paper hyper-parameters in KFACConfig)
data = SyntheticAutoencoderData(32, 6, 512)
batch = data.batch(0)
cfg = KFACConfig(lambda_init=1.0, t3=5)
opt = KFAC(mlp, cfg, family="bernoulli")
state = opt.init(params, batch)

# 3. jit the schedule pieces (Algorithm 2)
stats = jax.jit(opt.stats_grads)
refresh = jax.jit(opt.refresh_inverses)
update = jax.jit(lambda s, p, g, b, r: opt.apply_update(s, p, g, b, r))
lam = jax.jit(opt.lambda_step)

for step in range(20):
    rng = jax.random.fold_in(jax.random.PRNGKey(1), step)
    state, grads, metrics = stats(state, params, batch, rng)   # 1 fwd, 2 bwd
    if step % cfg.t3 == 0 or step < 3:                         # amortized d^3
        state = refresh(state)
    params, state, um = update(state, params, grads, batch, rng)
    if (step + 1) % cfg.t1 == 0:                               # LM rule
        state, _ = lam(state, params, batch, rng)
    print(f"step {step:2d}  loss={float(metrics['loss']):.4f}  "
          f"alpha={float(um['alpha']):.2e}  mu={float(um['mu']):.2e}  "
          f"lambda={float(state['lam']):.3f}")
