"""Paper S13 reproduction (miniature): deep autoencoder, K-FAC vs SGD+momentum.

The paper's benchmark problems (MNIST/CURVES/FACES autoencoders) need their
datasets; this offline container uses a synthetic low-rank-latent binary
dataset of the same character.  Every optimizer runs through the identical
``Trainer.fit`` loop (the swappable ``repro.optimizers`` API).  The claims
validated here:

  * K-FAC makes far more progress per iteration than tuned SGD+momentum
    (and than Adam);
  * block-tridiagonal beats block-diagonal per iteration;
  * momentum (S7) matters.

    PYTHONPATH=src:. python examples/autoencoder_kfac.py [steps]
"""
import sys

from benchmarks.bench_optimizer_race import run_adam, run_kfac, run_sgd

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 40

print(f"== deep autoencoder race ({steps} steps) ==")
best_sgd = None
for lr in (0.03, 0.1, 0.3):
    losses, secs = run_sgd(steps, lr=lr)
    print(f"sgd+momentum lr={lr}: final loss {losses[-1]:.4f} ({secs:.1f}s)")
    if best_sgd is None or losses[-1] < best_sgd:
        best_sgd = losses[-1]

losses, secs = run_adam(steps)
print(f"adam lr=0.01: final loss {losses[-1]:.4f} ({secs:.1f}s)")

for name, kw in [("kfac blkdiag", {}),
                 ("kfac tridiag", {"inv_mode": "tridiag"}),
                 ("kfac no-momentum", {"momentum": False})]:
    losses, secs = run_kfac(steps, **kw)
    print(f"{name}: final loss {losses[-1]:.4f} ({secs:.1f}s)")

losses, _ = run_kfac(steps)
assert losses[-1] < best_sgd, "K-FAC should beat tuned SGD per-iteration"
print(f"\nOK: K-FAC ({losses[-1]:.4f}) < best SGD ({best_sgd:.4f}) "
      f"after {steps} iterations — the paper's headline claim.")
